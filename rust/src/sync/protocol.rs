//! The pluggable sync-protocol registry: every synchronization protocol
//! registers a [`SyncProtocol`] implementation in [`PROTOCOLS`] and
//! self-describes — name, aliases, summary, tunable parameters, remote
//! capability — plus the wg-scope and remote-scope operation hooks the
//! engine dispatches through. The CLI (`srsp list-protocols`,
//! `--protocol <name>`, `--proto-param k=v`), the scenario layer, the
//! runner and the reports all resolve protocols through this one table;
//! no protocol enum exists to match on.
//!
//! Adding a protocol is now a registry entry: implement [`SyncProtocol`]
//! in a new `sync/<name>.rs` module (see [`scoped`](super::scoped) for
//! the smallest example, [`srsp_adaptive`](super::srsp_adaptive) for one
//! with parameters that composes existing protocol cores) and push it
//! into [`PROTOCOLS`]. Nothing in the engine, config, coordinator,
//! harness or CLI layers needs to change.

use std::fmt;

use super::ops::{SyncOp, SyncOutcome};
use crate::mem::MemSystem;
use crate::params::{ParamSpec, Params};

/// A registered synchronization protocol. Implementations live in their
/// own `sync/` module and self-describe everything the other layers need.
pub trait SyncProtocol: Sync {
    /// Canonical CLI name (`--protocol <name>`), lower-case.
    fn name(&self) -> &'static str;
    /// Extra accepted CLI spellings.
    fn aliases(&self) -> &'static [&'static str] {
        &[]
    }
    /// One-line description for `srsp list-protocols`.
    fn summary(&self) -> &'static str;
    /// Tunable parameters (`--proto-param k=v`; empty when none).
    fn params(&self) -> &'static [ParamSpec] {
        &[]
    }
    /// Does the protocol implement the remote-scope-promotion ops
    /// (`rem_acq`/`rem_rel`/`rem_ar`)?
    fn supports_remote(&self) -> bool {
        false
    }
    /// Do plain wg-scope sync ops transfer ownership lazily between CUs
    /// (the hLRC model), making cross-CU sharing correct without remote
    /// ops?
    fn lazy_wg_transfer(&self) -> bool {
        false
    }
    /// Perform a wg-scope scoped atomic. (cmp/sys scopes are
    /// protocol-independent and stay in [`super::ops`].)
    fn wg_op(&self, m: &mut MemSystem, s: &SyncOp) -> SyncOutcome;
    /// Perform a remote synchronization operation.
    fn remote_op(&self, m: &mut MemSystem, s: &SyncOp) -> SyncOutcome {
        let _ = (m, s);
        panic!(
            "remote scope promotion not supported by the {} protocol",
            self.name()
        )
    }
}

/// The static protocol table. Order is load-bearing for the stable
/// [`Protocol`] handles below: new protocols append, existing ones never
/// reorder.
pub static PROTOCOLS: &[&dyn SyncProtocol] = &[
    &super::scoped::ScopedOnly,
    &super::rsp_naive::RspNaive,
    &super::srsp::Srsp,
    &super::hlrc::Hlrc,
    &super::srsp_adaptive::SrspAdaptive,
];

/// Stable handle to a registered protocol (index into [`PROTOCOLS`]).
/// This is the *only* protocol identity in the crate — there is no enum
/// to `match` on; behavior differences go through the [`SyncProtocol`]
/// hooks.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Protocol(usize);

impl Protocol {
    /// Scoped acquire/release only; remote ops are *not* supported.
    pub const SCOPED_ONLY: Protocol = Protocol(0);
    /// Naive Remote-Scope-Promotion (Orr et al.).
    pub const RSP_NAIVE: Protocol = Protocol(1);
    /// Scalable RSP (this paper).
    pub const SRSP: Protocol = Protocol(2);
    /// heterogeneous Lazy Release Consistency (extension comparator).
    pub const HLRC: Protocol = Protocol(3);
    /// sRSP with eager-invalidation fallback under LR-TBL pressure.
    pub const SRSP_ADAPTIVE: Protocol = Protocol(4);

    /// The registered implementation behind this handle.
    pub fn proto(self) -> &'static dyn SyncProtocol {
        PROTOCOLS[self.0]
    }

    pub fn name(self) -> &'static str {
        self.proto().name()
    }
}

impl fmt::Debug for Protocol {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl fmt::Display for Protocol {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Every registered protocol, in registry order.
pub fn all() -> impl Iterator<Item = Protocol> {
    (0..PROTOCOLS.len()).map(Protocol)
}

/// Resolve a CLI name (canonical or alias, case-insensitive).
pub fn resolve(name: &str) -> Option<Protocol> {
    let lower = name.to_ascii_lowercase();
    all().find(|id| {
        let p = id.proto();
        p.name() == lower || p.aliases().contains(&lower.as_str())
    })
}

/// Resolve the subset of `overrides` that `protocol` declares against
/// its spec: defaults overlaid with the declared keys, undeclared keys
/// ignored (cells of a mixed grid only consume their own protocol's
/// keys). The single source of the "which `--proto-param` keys does this
/// protocol consume" rule — device construction and report rendering
/// both derive from it.
pub fn resolve_overrides(
    protocol: Protocol,
    overrides: &[(String, f64)],
) -> Result<Params, String> {
    let spec = protocol.proto().params();
    let declared: Vec<(String, f64)> = overrides
        .iter()
        .filter(|(k, _)| spec.iter().any(|p| p.key == k.as_str()))
        .cloned()
        .collect();
    Params::resolve(spec, &declared)
}

/// Render the subset of `overrides` that `protocol` declares as the
/// canonical `k=v;...` report string (empty when none apply).
pub fn overrides_display(protocol: Protocol, overrides: &[(String, f64)]) -> String {
    resolve_overrides(protocol, overrides)
        .map(|p| p.overrides_display())
        .unwrap_or_default()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeSet;

    #[test]
    fn registry_names_unique_and_resolvable() {
        let mut seen = BTreeSet::new();
        for id in all() {
            let p = id.proto();
            assert!(seen.insert(p.name()), "duplicate name {}", p.name());
            assert_eq!(resolve(p.name()), Some(id));
            assert_eq!(resolve(&p.name().to_uppercase()), Some(id));
            for alias in p.aliases() {
                assert_eq!(resolve(alias), Some(id), "alias {alias}");
            }
        }
        assert_eq!(resolve("bogus"), None);
    }

    #[test]
    fn classic_handles_stable() {
        // Saved scenario names and reports depend on these; never reorder.
        assert_eq!(Protocol::SCOPED_ONLY.name(), "scoped");
        assert_eq!(Protocol::RSP_NAIVE.name(), "rsp");
        assert_eq!(Protocol::SRSP.name(), "srsp");
        assert_eq!(Protocol::HLRC.name(), "hlrc");
        assert_eq!(Protocol::SRSP_ADAPTIVE.name(), "srsp-adaptive");
        assert_eq!(all().count(), 5);
    }

    #[test]
    fn capabilities_match_the_paper() {
        assert!(!Protocol::SCOPED_ONLY.proto().supports_remote());
        assert!(Protocol::RSP_NAIVE.proto().supports_remote());
        assert!(Protocol::SRSP.proto().supports_remote());
        assert!(Protocol::SRSP_ADAPTIVE.proto().supports_remote());
        assert!(!Protocol::HLRC.proto().supports_remote());
        assert!(Protocol::HLRC.proto().lazy_wg_transfer());
    }

    #[test]
    fn overrides_display_filters_to_declared_keys() {
        let overrides = vec![
            ("lr_tbl_entries".to_string(), 4.0),
            ("overflow_threshold".to_string(), 0.5),
        ];
        // The scoped protocol declares nothing.
        assert_eq!(overrides_display(Protocol::SCOPED_ONLY, &overrides), "");
        // sRSP declares the table sizes but not the adaptive threshold.
        assert_eq!(
            overrides_display(Protocol::SRSP, &overrides),
            "lr_tbl_entries=4"
        );
        // The adaptive protocol declares all three.
        assert_eq!(
            overrides_display(Protocol::SRSP_ADAPTIVE, &overrides),
            "lr_tbl_entries=4;overflow_threshold=0.5"
        );
    }
}
