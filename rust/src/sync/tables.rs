//! LR-TBL and PA-TBL: the two per-L1 hardware structures sRSP adds (§4).
//!
//! Both are small CAMs. Capacity overflow is handled *conservatively* — the
//! paper does not specify overflow behaviour, so we model the safe hardware
//! choice: a sticky overflow flag that degrades the table to
//! "assume every address matches" until the next full invalidate clears it.
//! Correctness is preserved (extra promotions/flushes are always safe);
//! only performance degrades. The `ablations` bench sweeps capacities.

use crate::mem::{Addr, Ticket};

/// Local Release Table: one entry per sync-variable address that received a
/// wg-scope release, holding the sFIFO ticket of the release's atomic write.
///
/// A *selective-flush(L)* request drains the sFIFO **up to** the recorded
/// ticket iff the table holds an entry for `L` — the termination marker of
/// §4.2.
#[derive(Debug, Clone)]
pub struct LrTbl {
    entries: Vec<(Addr, Ticket)>,
    capacity: usize,
    /// Sticky: an entry had to be dropped; unknown addresses must be
    /// treated as "might have had a local release" (full drain).
    overflowed: bool,
}

impl LrTbl {
    pub fn new(capacity: u32) -> Self {
        Self {
            entries: Vec::with_capacity(capacity as usize),
            capacity: capacity as usize,
            overflowed: false,
        }
    }

    /// Record (or refresh) the last wg-scope release to `addr` at sFIFO
    /// position `ticket`. Returns `true` on overflow (entry displaced).
    pub fn record(&mut self, addr: Addr, ticket: Ticket) -> bool {
        if let Some(e) = self.entries.iter_mut().find(|e| e.0 == addr) {
            e.1 = ticket;
            return false;
        }
        if self.capacity == 0 {
            self.overflowed = true;
            return true;
        }
        if self.entries.len() == self.capacity {
            // Displace the entry with the *oldest* ticket: its writes are
            // the most likely to already be drained. Conservative flag set.
            let oldest = self
                .entries
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| e.1)
                .map(|(i, _)| i)
                .unwrap();
            self.entries.swap_remove(oldest);
            self.overflowed = true;
            self.entries.push((addr, ticket));
            return true;
        }
        self.entries.push((addr, ticket));
        false
    }

    /// Ticket to drain to for a selective-flush of `addr`:
    /// * `Some(Some(t))` — entry found, drain up to `t`.
    /// * `Some(None)` — overflowed table: drain *everything* (conservative).
    /// * `None` — definite miss, nothing to drain.
    pub fn lookup(&self, addr: Addr) -> Option<Option<Ticket>> {
        if let Some(e) = self.entries.iter().find(|e| e.0 == addr) {
            return Some(Some(e.1));
        }
        if self.overflowed {
            return Some(None);
        }
        None
    }

    /// Invalidate clears everything, including the sticky flag (§4.4: every
    /// cache invalidation clears both tables).
    pub fn clear(&mut self) {
        self.entries.clear();
        self.overflowed = false;
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn has_overflowed(&self) -> bool {
        self.overflowed
    }

    /// Invariant helper: every recorded ticket is below the sFIFO frontier.
    pub fn max_ticket(&self) -> Option<Ticket> {
        self.entries.iter().map(|e| e.1).max()
    }
}

/// Result of recording an address in the PA-TBL.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PaRecord {
    /// Entry stored (or already present).
    Recorded,
    /// Table full: the L1 controller must perform an *eager* full
    /// invalidate (which clears both tables, discharging every deferred
    /// promotion obligation) and then record. Correct — an invalidate is
    /// always a legal over-approximation of a promotion — and local, so
    /// the scalability of the selective scheme is preserved. A sticky
    /// "promote everything" flag was measurably worse: with one deque per
    /// CU the broadcasts fill every table and the device degenerates to
    /// global scope.
    NeedsInvalidate,
}

/// Promoted Acquire Table: addresses whose **next** wg-scope acquire must be
/// promoted to global scope (§4.2–4.4).
///
/// A hit forces: full L1 invalidate (pulling fresh data from L2 afterwards)
/// + the atomic performed at L2. A miss keeps the acquire at the L1.
#[derive(Debug, Clone)]
pub struct PaTbl {
    entries: Vec<Addr>,
    capacity: usize,
}

impl PaTbl {
    pub fn new(capacity: u32) -> Self {
        Self {
            entries: Vec::with_capacity(capacity as usize),
            capacity: capacity as usize,
        }
    }

    /// Record that the next wg-scope acquire of `addr` needs promotion.
    pub fn record(&mut self, addr: Addr) -> PaRecord {
        if self.entries.contains(&addr) {
            return PaRecord::Recorded;
        }
        if self.entries.len() >= self.capacity {
            return PaRecord::NeedsInvalidate;
        }
        self.entries.push(addr);
        PaRecord::Recorded
    }

    /// Must a wg-scope acquire of `addr` be promoted?
    pub fn needs_promotion(&self, addr: Addr) -> bool {
        self.entries.contains(&addr)
    }

    pub fn clear(&mut self) {
        self.entries.clear();
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.entries.len() >= self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_tbl_record_and_refresh() {
        let mut t = LrTbl::new(4);
        assert!(!t.record(0x100, 5));
        assert_eq!(t.lookup(0x100), Some(Some(5)));
        // Refresh with a newer ticket.
        t.record(0x100, 9);
        assert_eq!(t.lookup(0x100), Some(Some(9)));
        assert_eq!(t.len(), 1);
        assert_eq!(t.lookup(0x200), None);
    }

    #[test]
    fn lr_tbl_overflow_is_conservative() {
        let mut t = LrTbl::new(2);
        t.record(0x100, 1);
        t.record(0x200, 2);
        assert!(t.record(0x300, 3)); // overflow: 0x100 (oldest ticket) displaced
        assert!(t.has_overflowed());
        // The displaced address now reads as "drain everything".
        assert_eq!(t.lookup(0x100), Some(None));
        // Survivors still give precise tickets.
        assert_eq!(t.lookup(0x300), Some(Some(3)));
    }

    #[test]
    fn lr_tbl_clear_resets_overflow() {
        let mut t = LrTbl::new(1);
        t.record(0x100, 1);
        t.record(0x200, 2);
        assert!(t.has_overflowed());
        t.clear();
        assert!(!t.has_overflowed());
        assert_eq!(t.lookup(0x100), None);
        assert!(t.is_empty());
    }

    #[test]
    fn zero_capacity_lr_tbl_always_conservative() {
        let mut t = LrTbl::new(0);
        assert!(t.record(0x100, 1));
        assert_eq!(t.lookup(0x100), Some(None));
        assert_eq!(t.lookup(0x999), Some(None));
    }

    #[test]
    fn pa_tbl_basic() {
        let mut t = PaTbl::new(4);
        assert!(!t.needs_promotion(0x100));
        assert_eq!(t.record(0x100), PaRecord::Recorded);
        assert!(t.needs_promotion(0x100));
        assert!(!t.needs_promotion(0x200));
        assert_eq!(t.record(0x100), PaRecord::Recorded); // idempotent
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn pa_tbl_overflow_demands_invalidate() {
        let mut t = PaTbl::new(1);
        assert_eq!(t.record(0x100), PaRecord::Recorded);
        assert!(t.is_full());
        // Re-recording a present address is fine even when full.
        assert_eq!(t.record(0x100), PaRecord::Recorded);
        // A new address demands the eager invalidate.
        assert_eq!(t.record(0x200), PaRecord::NeedsInvalidate);
        // The invalidate clears the table; then recording succeeds.
        t.clear();
        assert_eq!(t.record(0x200), PaRecord::Recorded);
        assert!(!t.needs_promotion(0x100));
    }

    #[test]
    fn lr_tbl_max_ticket() {
        let mut t = LrTbl::new(4);
        assert_eq!(t.max_ticket(), None);
        t.record(1, 10);
        t.record(2, 30);
        t.record(3, 20);
        assert_eq!(t.max_ticket(), Some(30));
    }
}
