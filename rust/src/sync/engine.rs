//! The synchronization engine: thin dispatch from scoped/remote
//! operation requests to the registered [`SyncProtocol`] hooks.
//!
//! Historically this module was an ~900-line monolith interleaving every
//! protocol's logic behind `match protocol` arms; the per-protocol logic
//! now lives in its own module ([`scoped`](super::scoped),
//! [`rsp_naive`](super::rsp_naive), [`srsp`](super::srsp),
//! [`hlrc`](super::hlrc), [`srsp_adaptive`](super::srsp_adaptive)) behind
//! the [`SyncProtocol`] trait, sharing the protocol-independent scoped-op
//! core in [`ops`](super::ops). This module only:
//!
//! * bundles the request into a [`SyncOp`],
//! * maintains the scope-level operation counters,
//! * routes wg-scope and remote ops to the protocol hooks (cmp/sys scope
//!   are protocol-independent and go straight to the shared core),
//! * charges the Fig. 6 overhead accounting for remote ops.
//!
//! [`SyncProtocol`]: super::protocol::SyncProtocol

use super::ops;
pub use super::ops::{SyncOp, SyncOutcome};
use super::protocol::Protocol;
use super::scope::{AtomicOp, MemOrder, Scope};
use crate::mem::{Addr, MemSystem};
use crate::sim::{Cycle, TraceKind};

/// Perform a scoped atomic (§2.2). `scope` ∈ {Wg, Cmp, Sys}; remote ops
/// go through [`remote_op`].
#[allow(clippy::too_many_arguments)]
pub fn sync_op(
    m: &mut MemSystem,
    protocol: Protocol,
    cu: u32,
    addr: Addr,
    op: AtomicOp,
    order: MemOrder,
    scope: Scope,
    operand: u32,
    cmp: u32,
    at: Cycle,
) -> SyncOutcome {
    let s = SyncOp {
        cu,
        addr,
        op,
        order,
        operand,
        cmp,
        at,
    };
    match scope {
        Scope::Wg => {
            if order.acquires() {
                m.stats.wg_acquires += 1;
                m.trace.emit(at, cu, TraceKind::WgAcquire, addr, 0);
            }
            if order.releases() {
                m.stats.wg_releases += 1;
                m.trace.emit(at, cu, TraceKind::WgRelease, addr, 0);
            }
            protocol.proto().wg_op(m, &s)
        }
        // cmp/sys scope are identical under every protocol (§2.2).
        Scope::Cmp => ops::cmp_scope_op(m, &s),
        Scope::Sys => ops::sys_scope_op(m, &s),
    }
}

/// Perform a remote synchronization operation (`rem_acq`, `rem_rel`,
/// `rem_ar`) on `addr` from `cu`. `order` selects which: `Acquire` →
/// rem_acq, `Release` → rem_rel, `AcqRel` → rem_ar.
///
/// Panics if the protocol does not implement remote-scope promotion
/// (e.g. scoped-only or hLRC) — scenarios without it must use cmp scope.
#[allow(clippy::too_many_arguments)]
pub fn remote_op(
    m: &mut MemSystem,
    protocol: Protocol,
    cu: u32,
    addr: Addr,
    op: AtomicOp,
    order: MemOrder,
    operand: u32,
    cmp: u32,
    at: Cycle,
) -> SyncOutcome {
    match order {
        MemOrder::Acquire => {
            m.stats.remote_acquires += 1;
            m.trace.emit(at, cu, TraceKind::RemoteAcquire, addr, 0);
        }
        MemOrder::Release => {
            m.stats.remote_releases += 1;
            m.trace.emit(at, cu, TraceKind::RemoteRelease, addr, 0);
        }
        MemOrder::AcqRel => {
            m.stats.remote_acqrels += 1;
            m.trace.emit(at, cu, TraceKind::RemoteAcqRel, addr, 0);
        }
        MemOrder::Relaxed => panic!("remote op requires acquire/release semantics"),
    }
    let s = SyncOp {
        cu,
        addr,
        op,
        order,
        operand,
        cmp,
        at,
    };
    let out = protocol.proto().remote_op(m, &s);
    ops::charge_overhead(m, at, out.done);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeviceConfig;
    use crate::mem::MemSystem;

    fn sys(protocol: Protocol) -> (MemSystem, Protocol) {
        (MemSystem::new(DeviceConfig::small()), protocol)
    }

    const LOCK: Addr = 0x1000;
    const DATA: Addr = 0x2000;

    /// The paper's running example (§4): wg0 on CU0 is the local sharer of
    /// DATA guarded by LOCK; wg1 on CU1 steals it with remote ops.
    fn local_sharer_writes(m: &mut MemSystem, p: Protocol, t: Cycle) -> Cycle {
        // Update Y, then wg-scope release of the lock (atomic_ST_rel_wg).
        let t = m.l1_write(0, DATA, 4, 41, t);
        let out = sync_op(
            m, p, 0, LOCK, AtomicOp::Store, MemOrder::Release, Scope::Wg, 0, 0, t,
        );
        out.done
    }

    #[test]
    fn srsp_remote_acquire_sees_local_release() {
        let (mut m, p) = sys(Protocol::SRSP);
        let t = local_sharer_writes(&mut m, p, 0);
        // LR-TBL recorded the release.
        assert_eq!(m.cu(0).lr_tbl.len(), 1);

        // CU1: atomic_CAS_rem_acq_cmp(L, 0 -> 1).
        let out = remote_op(
            &mut m, p, 1, LOCK, AtomicOp::Cas, MemOrder::Acquire, 1, 0, t,
        );
        assert_eq!(out.value, 0, "CAS must see the released lock");
        // The promoted data write is now globally visible.
        let (v, _) = m.l1_read(1, DATA, 4, out.done);
        assert_eq!(v, 41, "remote acquire must pull the local sharer's data");
        // PA-TBL on CU0 now forces promotion of its next local acquire.
        assert!(m.cu(0).pa_tbl.needs_promotion(LOCK));
        assert_eq!(m.stats.selective_flush_drains, 1);
    }

    #[test]
    fn srsp_local_acquire_promoted_after_remote() {
        let (mut m, p) = sys(Protocol::SRSP);
        let t = local_sharer_writes(&mut m, p, 0);
        let out = remote_op(
            &mut m, p, 1, LOCK, AtomicOp::Cas, MemOrder::Acquire, 1, 0, t,
        );
        // CU0 tries to re-acquire locally: PA-TBL hit → promoted to L2 →
        // must observe the lock taken by CU1 (no stale-local false success).
        let out0 = sync_op(
            &mut m, p, 0, LOCK, AtomicOp::Cas, MemOrder::Acquire, Scope::Wg, 1, 0, out.done,
        );
        assert_eq!(out0.value, 1, "promoted acquire must see remote CAS");
        assert_eq!(m.stats.promoted_acquires, 1);
        // Tables cleared by the promotion's invalidate.
        assert!(!m.cu(0).pa_tbl.needs_promotion(LOCK));
    }

    #[test]
    fn srsp_remote_release_hands_data_back() {
        let (mut m, p) = sys(Protocol::SRSP);
        let t = local_sharer_writes(&mut m, p, 0);
        let acq = remote_op(&mut m, p, 1, LOCK, AtomicOp::Cas, MemOrder::Acquire, 1, 0, t);
        assert_eq!(acq.value, 0);
        // CU1 updates the data in its critical section, then rem_rel.
        let t = m.l1_write(1, DATA, 4, 99, acq.done);
        let rel = remote_op(&mut m, p, 1, LOCK, AtomicOp::Store, MemOrder::Release, 0, 0, t);
        // CU0 re-acquires locally: PA-TBL (set by selective-invalidate)
        // promotes it; the fresh data must be visible.
        let out0 = sync_op(
            &mut m, p, 0, LOCK, AtomicOp::Cas, MemOrder::Acquire, Scope::Wg, 1, 0, rel.done,
        );
        assert_eq!(out0.value, 0, "lock was released remotely");
        let (v, _) = m.l1_read(0, DATA, 4, out0.done);
        assert_eq!(v, 99, "thief's update must be visible after promotion");
    }

    #[test]
    fn naive_rsp_same_semantics() {
        let (mut m, p) = sys(Protocol::RSP_NAIVE);
        let t = local_sharer_writes(&mut m, p, 0);
        let acq = remote_op(&mut m, p, 1, LOCK, AtomicOp::Cas, MemOrder::Acquire, 1, 0, t);
        assert_eq!(acq.value, 0);
        let (v, t2) = m.l1_read(1, DATA, 4, acq.done);
        assert_eq!(v, 41);
        // Owner's local re-acquire: naive RSP invalidated every L1 during
        // rem_acq, so CU0 misses to L2 and sees the taken lock.
        let out0 = sync_op(
            &mut m, p, 0, LOCK, AtomicOp::Cas, MemOrder::Acquire, Scope::Wg, 1, 0, t2,
        );
        assert_eq!(out0.value, 1);
    }

    #[test]
    fn naive_invalidates_all_srsp_does_not() {
        // Warm unrelated data into every L1, then do one remote acquire.
        // Naive RSP destroys all that locality; sRSP keeps it.
        for (proto, invalidates_all) in [(Protocol::RSP_NAIVE, true), (Protocol::SRSP, false)] {
            let (mut m, p) = sys(proto);
            let mut t = local_sharer_writes(&mut m, p, 0);
            for cu in 0..4 {
                for i in 0..8u64 {
                    let (_, tt) = m.l1_read(cu, 0x9000 + cu as u64 * 0x1000 + i * 64, 4, t);
                    t = tt;
                }
            }
            let before_inv = m.stats.lines_invalidated;
            let _ = remote_op(&mut m, p, 1, LOCK, AtomicOp::Cas, MemOrder::Acquire, 1, 0, t);
            let invalidated = m.stats.lines_invalidated - before_inv;
            if invalidates_all {
                assert!(
                    invalidated > 16,
                    "naive RSP must invalidate every L1 (got {invalidated})"
                );
            } else {
                assert!(
                    invalidated <= 16,
                    "sRSP must only invalidate the requester (got {invalidated})"
                );
            }
        }
    }

    #[test]
    fn cmp_scope_is_protocol_independent_and_correct() {
        for proto in [Protocol::SCOPED_ONLY, Protocol::RSP_NAIVE, Protocol::SRSP] {
            let (mut m, p) = sys(proto);
            // CU0 releases at cmp scope; CU2 acquires at cmp scope.
            let t = m.l1_write(0, DATA, 4, 7, 0);
            let rel = sync_op(
                &mut m, p, 0, LOCK, AtomicOp::Store, MemOrder::Release, Scope::Cmp, 1, 0, t,
            );
            let acq = sync_op(
                &mut m, p, 2, LOCK, AtomicOp::Load, MemOrder::Acquire, Scope::Cmp, 0, 0, rel.done,
            );
            assert_eq!(acq.value, 1);
            let (v, _) = m.l1_read(2, DATA, 4, acq.done);
            assert_eq!(v, 7, "cmp acquire/release pair must transfer data ({proto:?})");
        }
    }

    #[test]
    fn srsp_cheaper_than_naive_under_warm_caches() {
        let mut costs = Vec::new();
        for proto in [Protocol::RSP_NAIVE, Protocol::SRSP] {
            let (mut m, p) = sys(proto);
            let mut t = local_sharer_writes(&mut m, p, 0);
            // Dirty data on *other* CUs that naive RSP will pointlessly drain.
            for cu in 1..4 {
                for i in 0..12u64 {
                    t = m.l1_write(cu, 0x20000 + cu as u64 * 0x1000 + i * 64, 4, 1, t);
                }
            }
            let before = m.stats.sync_overhead_cycles;
            let _ = remote_op(&mut m, p, 1, LOCK, AtomicOp::Cas, MemOrder::Acquire, 1, 0, t);
            costs.push(m.stats.sync_overhead_cycles - before);
        }
        assert!(
            costs[1] < costs[0],
            "sRSP promotion ({}) must be cheaper than naive ({})",
            costs[1],
            costs[0]
        );
    }

    #[test]
    fn remote_op_without_own_lr_entry_broadcasts() {
        let (mut m, p) = sys(Protocol::SRSP);
        let t = local_sharer_writes(&mut m, p, 0);
        let _ = remote_op(&mut m, p, 1, LOCK, AtomicOp::Cas, MemOrder::Acquire, 1, 0, t);
        assert_eq!(m.stats.selective_flush_requests, 1);
        // 3 other CUs probed: one drain (CU0) + two nops (CU2, CU3).
        assert_eq!(m.stats.selective_flush_nops, 2);
        assert_eq!(m.stats.selective_flush_drains, 1);
    }

    #[test]
    fn same_cu_local_sharer_skips_broadcast() {
        let (mut m, p) = sys(Protocol::SRSP);
        // Local sharer on CU1; the remote op also issued from CU1.
        let t = m.l1_write(1, DATA, 4, 5, 0);
        let rel = sync_op(
            &mut m, p, 1, LOCK, AtomicOp::Store, MemOrder::Release, Scope::Wg, 0, 0, t,
        );
        let _ = remote_op(
            &mut m, p, 1, LOCK, AtomicOp::Cas, MemOrder::Acquire, 1, 0, rel.done,
        );
        assert_eq!(
            m.stats.selective_flush_requests, 0,
            "same-CU local sharer: §4.2 optimization skips the broadcast"
        );
    }

    const LOCK2: Addr = 0x3000;
    const DATA2: Addr = 0x4000;

    fn srsp_sys_with(lr: u32, pa: u32) -> MemSystem {
        MemSystem::new(DeviceConfig {
            lr_tbl_entries: lr,
            pa_tbl_entries: pa,
            ..DeviceConfig::small()
        })
    }

    #[test]
    fn lr_tbl_overflow_conservative_drain_stays_correct() {
        // Capacity 1: the second wg-scope release displaces the first;
        // the displaced address must still be found (conservative "drain
        // everything") by a remote acquire.
        let mut m = srsp_sys_with(1, 16);
        let p = Protocol::SRSP;
        let t = m.l1_write(0, DATA, 4, 41, 0);
        let t = sync_op(
            &mut m, p, 0, LOCK, AtomicOp::Store, MemOrder::Release, Scope::Wg, 1, 0, t,
        )
        .done;
        let t = m.l1_write(0, DATA2, 4, 42, t);
        let t = sync_op(
            &mut m, p, 0, LOCK2, AtomicOp::Store, MemOrder::Release, Scope::Wg, 1, 0, t,
        )
        .done;
        assert_eq!(m.stats.lr_tbl_overflows, 1, "capacity-1 table must overflow");
        assert!(m.cu(0).lr_tbl.has_overflowed());

        // LOCK carried the older ticket and was displaced; the remote
        // acquire must still drain CU0 and observe both the lock and the
        // guarded data.
        let out = remote_op(&mut m, p, 1, LOCK, AtomicOp::Cas, MemOrder::Acquire, 2, 1, t);
        assert_eq!(out.value, 1, "released lock must be visible");
        assert!(m.stats.selective_flush_drains >= 1, "overflow must drain, not nop");
        let (v, _) = m.l1_read(1, DATA, 4, out.done);
        assert_eq!(v, 41, "displaced entry must not lose the release's data");
    }

    #[test]
    fn requester_side_overflow_must_not_skip_the_broadcast() {
        // lr_tbl_entries = 0: every table is sticky-overflowed from the
        // first release. The requester's own conservative `Some(None)`
        // answer must NOT be mistaken for "the local sharer is me" — the
        // true sharer (CU0) still has the lock value in its sFIFO, and
        // skipping the selective-flush broadcast would read it stale.
        let mut m = srsp_sys_with(0, 16);
        let p = Protocol::SRSP;
        let t = m.l1_write(0, DATA, 4, 41, 0);
        let t = sync_op(
            &mut m, p, 0, LOCK, AtomicOp::Store, MemOrder::Release, Scope::Wg, 1, 0, t,
        )
        .done;
        // Overflow the *requester's* table too (a release on an unrelated
        // variable).
        let t = m.l1_write(1, DATA2, 4, 9, t);
        let t = sync_op(
            &mut m, p, 1, LOCK2, AtomicOp::Store, MemOrder::Release, Scope::Wg, 1, 0, t,
        )
        .done;
        assert!(m.cu(1).lr_tbl.has_overflowed());
        assert!(m.stats.lr_tbl_overflows >= 2);

        let out = remote_op(&mut m, p, 1, LOCK, AtomicOp::Cas, MemOrder::Acquire, 2, 1, t);
        assert_eq!(
            m.stats.selective_flush_requests, 1,
            "conservative own-table answer must still broadcast"
        );
        assert_eq!(out.value, 1, "CAS must see CU0's released lock");
        let (v, _) = m.l1_read(1, DATA, 4, out.done);
        assert_eq!(v, 41, "CU0's sFIFO must have been drained");
    }

    #[test]
    fn pa_tbl_overflow_eager_invalidate_keeps_correctness() {
        // Capacity 1: arming a second address at a full table forces the
        // eager local invalidate (discharging the first obligation) and
        // then records the second. Both locks' data must stay visible.
        let mut m = srsp_sys_with(16, 1);
        let p = Protocol::SRSP;
        let t = m.l1_write(1, DATA, 4, 7, 0);
        let t = remote_op(&mut m, p, 1, LOCK, AtomicOp::Store, MemOrder::Release, 1, 0, t).done;
        let t = m.l1_write(1, DATA2, 4, 9, t);
        let t = remote_op(&mut m, p, 1, LOCK2, AtomicOp::Store, MemOrder::Release, 1, 0, t).done;
        // Each of the 3 other CUs had LOCK armed and overflowed on LOCK2.
        assert_eq!(m.stats.pa_tbl_overflows, 3);
        assert!(m.cu(0).pa_tbl.needs_promotion(LOCK2));
        assert!(
            !m.cu(0).pa_tbl.needs_promotion(LOCK),
            "eager invalidate discharged the first obligation"
        );

        // LOCK2: promoted via the PA-TBL hit.
        let out = sync_op(
            &mut m, p, 0, LOCK2, AtomicOp::Load, MemOrder::Acquire, Scope::Wg, 0, 0, t,
        );
        assert_eq!(out.value, 1);
        let (v, t) = m.l1_read(0, DATA2, 4, out.done);
        assert_eq!(v, 9);
        // LOCK: obligation was discharged by the eager invalidate — the
        // acquire stays local but misses to the L2 and reads fresh.
        let out = sync_op(
            &mut m, p, 0, LOCK, AtomicOp::Load, MemOrder::Acquire, Scope::Wg, 0, 0, t,
        );
        assert_eq!(out.value, 1);
        let (v, _) = m.l1_read(0, DATA, 4, out.done);
        assert_eq!(v, 7);
    }

    #[test]
    fn zero_capacity_pa_tbl_promotes_eagerly() {
        // pa_tbl_entries = 0: nothing can be deferred; every arming
        // degenerates to an immediate invalidate at the target. Must not
        // panic, must count overflows, must stay correct.
        let mut m = srsp_sys_with(16, 0);
        let p = Protocol::SRSP;
        let t = m.l1_write(1, DATA, 4, 5, 0);
        let t = remote_op(&mut m, p, 1, LOCK, AtomicOp::Store, MemOrder::Release, 1, 0, t).done;
        assert_eq!(m.stats.pa_tbl_overflows, 3, "one per non-requesting CU");
        assert!(m.cu(0).pa_tbl.is_empty());
        let out = sync_op(
            &mut m, p, 0, LOCK, AtomicOp::Load, MemOrder::Acquire, Scope::Wg, 0, 0, t,
        );
        assert_eq!(out.value, 1, "eager invalidate must publish the release");
        let (v, _) = m.l1_read(0, DATA, 4, out.done);
        assert_eq!(v, 5);
    }

    #[test]
    #[should_panic(expected = "not supported")]
    fn scoped_only_rejects_remote_ops() {
        let (mut m, p) = sys(Protocol::SCOPED_ONLY);
        let _ = remote_op(&mut m, p, 0, LOCK, AtomicOp::Cas, MemOrder::Acquire, 1, 0, 0);
    }

    #[test]
    fn rem_ar_full_fence_semantics() {
        for proto in [Protocol::RSP_NAIVE, Protocol::SRSP] {
            let (mut m, p) = sys(proto);
            let t = local_sharer_writes(&mut m, p, 0);
            // rem_ar: fetch-add on a counter with full fence.
            let t2 = m.l1_write(1, DATA + 64, 4, 11, t);
            let out = remote_op(
                &mut m, p, 1, LOCK, AtomicOp::Add, MemOrder::AcqRel, 1, 0, t2,
            );
            assert_eq!(out.value, 0);
            // Both directions visible: CU1 saw CU0's data...
            let (v, t3) = m.l1_read(1, DATA, 4, out.done);
            assert_eq!(v, 41);
            // ...and CU0's next promoted acquire sees CU1's write.
            let out0 = sync_op(
                &mut m, p, 0, LOCK, AtomicOp::Load, MemOrder::Acquire, Scope::Wg, 0, 0, t3,
            );
            assert_eq!(out0.value, 1);
            let (v2, _) = m.l1_read(0, DATA + 64, 4, out0.done);
            assert_eq!(v2, 11, "{proto:?}: rem_ar must publish the thief's writes");
        }
    }
}
