//! Protocol engines: scoped and remote synchronization operations (§2.2, §4).
//!
//! Each operation is orchestrated over [`MemSystem`] primitives and is
//! parameterized by [`Protocol`]:
//!
//! | op                | ScopedOnly          | RspNaive                       | Srsp                                  |
//! |-------------------|---------------------|--------------------------------|---------------------------------------|
//! | wg acquire        | L1 atomic           | L1 atomic                      | PA-TBL check → maybe promote (§4.4)   |
//! | wg release        | L1 atomic           | L1 atomic                      | + LR-TBL record (§4.1)                |
//! | cmp acquire       | inv own L1 + L2 op  | same                           | same                                  |
//! | cmp release       | flush own L1 + L2 op| same                           | same                                  |
//! | remote acquire    | —                   | flush+inv **all** L1s + L2 op  | selective-flush bcast (§4.2) + L2 op  |
//! | remote release    | —                   | flush own + L2 op + inv **all**| flush own + L2 op + sel-inv bcast (§4.3) |
//! | remote acq+rel    | —                   | both of the above              | both of the above                     |
//!
//! Overhead accounting: every cycle beyond what the *same atomic at wg
//! scope on an L1 hit* would cost is charged to
//! `stats.sync_overhead_cycles` — the Fig. 6 metric.

use super::scope::{AtomicOp, MemOrder, Scope};
use crate::config::Protocol;
use crate::mem::{line_of, Addr, MemSystem};
use crate::sim::Cycle;

/// Result of a synchronization operation.
#[derive(Debug, Clone, Copy)]
pub struct SyncOutcome {
    /// Value returned to the program (old value for RMW ops).
    pub value: u32,
    /// Completion cycle.
    pub done: Cycle,
}

/// Perform a scoped atomic (§2.2). `scope` ∈ {Wg, Cmp}; remote ops go
/// through [`remote_op`].
pub fn sync_op(
    m: &mut MemSystem,
    protocol: Protocol,
    cu: u32,
    addr: Addr,
    op: AtomicOp,
    order: MemOrder,
    scope: Scope,
    operand: u32,
    cmp: u32,
    at: Cycle,
) -> SyncOutcome {
    match scope {
        Scope::Wg => wg_scope_op(m, protocol, cu, addr, op, order, operand, cmp, at),
        Scope::Cmp => cmp_scope_op(m, cu, addr, op, order, operand, cmp, at),
        Scope::Sys => sys_scope_op(m, cu, addr, op, order, operand, cmp, at),
    }
}

/// Baseline cost of the same atomic if it were a wg-scope L1 hit — used to
/// compute promotion/synchronization overhead.
fn plain_cost(m: &MemSystem) -> u64 {
    m.cfg.l1_latency + 1
}

fn charge_overhead(m: &mut MemSystem, at: Cycle, done: Cycle) {
    let plain = plain_cost(m);
    let took = done.saturating_sub(at);
    m.stats.sync_overhead_cycles += took.saturating_sub(plain);
}

// ----------------------------------------------------------------------
// wg (local) scope
// ----------------------------------------------------------------------

#[allow(clippy::too_many_arguments)]
fn wg_scope_op(
    m: &mut MemSystem,
    protocol: Protocol,
    cu: u32,
    addr: Addr,
    op: AtomicOp,
    order: MemOrder,
    operand: u32,
    cmp: u32,
    at: Cycle,
) -> SyncOutcome {
    if order.acquires() {
        m.stats.wg_acquires += 1;
    }
    if order.releases() {
        m.stats.wg_releases += 1;
    }

    // §4.4: under sRSP a wg-scope acquire first consults the PA-TBL; a hit
    // promotes it to global scope (full L1 invalidate + atomic at L2).
    if protocol == Protocol::Srsp && order.acquires() {
        // The PA-TBL lookup itself costs one cycle (CAM probe).
        let t = at + 1;
        if m.cu(cu).pa_tbl.needs_promotion(addr) {
            m.stats.promoted_acquires += 1;
            let t = m.invalidate_l1(cu, t); // also clears LR-TBL + PA-TBL
            let (value, done) = m.l2_atomic(cu, addr, op, operand, cmp, t);
            charge_overhead(m, at, done);
            // A promoted acquire that also releases (AcqRel) performed its
            // write at the L2 already; nothing further needed.
            if order.releases() {
                record_release_if_srsp(m, protocol, cu, addr, None);
            }
            return SyncOutcome { value, done };
        }
        m.stats.local_acquires += 1;
        let (value, ticket, done) = m.l1_atomic(cu, addr, op, operand, cmp, t);
        if op.writes_given(value, operand, cmp) {
            record_release_if_srsp(m, protocol, cu, addr, Some(ticket));
        }
        charge_overhead(m, at, done);
        return SyncOutcome { value, done };
    }

    // hLRC (extension): wg-scope sync ops go to the *owning* L1; a
    // non-owner's op lazily transfers ownership — the previous owner
    // flushes (publishing its releases), the requester invalidates
    // (acquire side), the op completes at the L2, and subsequent ops by
    // the new owner are L1-local again.
    if protocol == Protocol::Hlrc {
        return hlrc_op(m, cu, addr, op, order, operand, cmp, at);
    }

    // Plain wg-scope atomic at the L1 (all protocols).
    let (value, ticket, done) = m.l1_atomic(m_cu(cu), addr, op, operand, cmp, at);
    // §4.1: under sRSP a wg-scope sync *write* records (addr → sFIFO
    // ticket) in the LR-TBL so a later remote acquire can selectively
    // flush. Releases are the textbook case, but an acquire-CAS's store
    // (e.g. taking a lock: CAS_acq_wg 0→1) must be recorded too —
    // otherwise a remote acquire arriving before the owner's first
    // release finds an empty LR-TBL, skips the drain, reads the stale
    // unlocked value from the L2 and breaks mutual exclusion. (Naive RSP
    // is immune: it always drains every L1.)
    if op.writes_given(value, operand, cmp) {
        record_release_if_srsp(m, protocol, cu, addr, Some(ticket));
    }
    charge_overhead(m, at, done);
    SyncOutcome { value, done }
}

#[inline]
fn m_cu(cu: u32) -> u32 {
    cu
}

/// hLRC wg-scope synchronization (extension protocol, paper §6 related
/// work). Ownership of the sync variable lives in a registry at the L2:
///
/// * requester already owns it → plain L1 atomic (the fast path hLRC is
///   built around);
/// * otherwise → lazy transfer: previous owner's L1 is flushed (its
///   releases become globally visible), the requester's L1 is
///   invalidated (acquire side), the atomic completes at the L2, and the
///   requester becomes the owner;
/// * registry eviction (capacity) forces the evictee's owner to flush —
///   the replacement-policy sensitivity the paper criticizes.
#[allow(clippy::too_many_arguments)]
fn hlrc_op(
    m: &mut MemSystem,
    cu: u32,
    addr: Addr,
    op: AtomicOp,
    order: MemOrder,
    operand: u32,
    cmp: u32,
    at: Cycle,
) -> SyncOutcome {
    match m.hlrc_owner(addr) {
        Some(owner) if owner == cu => {
            // Fast path: L1-local.
            m.stats.bump("hlrc_local_ops", 1);
            let (value, _ticket, done) = m.l1_atomic(cu, addr, op, operand, cmp, at);
            charge_overhead(m, at, done);
            SyncOutcome { value, done }
        }
        prev => {
            // Lazy transfer through the L2 registry.
            m.stats.bump("hlrc_transfers", 1);
            let line = line_of(addr);
            // Registry probe at the L2.
            let t_req = m.xbar_hop(cu, at);
            let mut t_ready = m.l2_control_hop(line, t_req) + 2;
            if let Some(owner) = prev {
                // Previous owner publishes everything up to its last
                // sync op on this variable (full flush: hLRC keeps no
                // per-variable tickets).
                let t_arrive = m.xbar_hop(owner, t_ready);
                let t_flush = m.full_flush_l1(owner, t_arrive);
                // The owner's cached copy of the line must go, or its
                // later local reads would see a stale value.
                if let Some(wb) = m.cu_mut(owner).l1.invalidate_line(line) {
                    // Flush above already cleaned it; belt and braces.
                    m.backing.write_line_masked(wb.line, wb.mask, &wb.data);
                }
                t_ready = t_ready.max(m.xbar_hop(owner, t_flush));
            }
            // Requester acquires: drop its stale state.
            let t_own = m.invalidate_l1(cu, at);
            let t_ready = t_ready.max(t_own);
            // Claim ownership; a capacity eviction forces the evictee's
            // owner to flush (it loses its exclusive hold).
            if let Some((_, evicted_owner)) = m.hlrc_claim(addr, cu) {
                m.stats.bump("hlrc_evictions", 1);
                m.full_flush_l1(evicted_owner, t_ready);
            }
            // The op itself completes at the L2 (the transfer point).
            let (value, done) = m.l2_atomic(cu, addr, op, operand, cmp, t_ready);
            let _ = order;
            charge_overhead(m, at, done);
            SyncOutcome { value, done }
        }
    }
}

/// Record a promoted-acquire obligation at `target`'s PA-TBL. A full
/// table forces an eager local invalidate first (clearing both tables —
/// every deferred obligation is discharged), then records.
fn record_pa(m: &mut MemSystem, target: u32, addr: Addr, at: Cycle) -> Cycle {
    use crate::sync::tables::PaRecord;
    m.stats.pa_tbl_insertions += 1;
    let mut t = at;
    if m.cu(target).pa_tbl.is_full() && !m.cu(target).pa_tbl.needs_promotion(addr) {
        m.stats.pa_tbl_overflows += 1;
        t = m.invalidate_l1(target, t);
    }
    match m.cu_mut(target).pa_tbl.record(addr) {
        PaRecord::Recorded => t,
        // Only reachable with `pa_tbl_entries = 0`: nothing can ever be
        // recorded, but the eager invalidate above already discharged the
        // obligation — the target's next access misses to the L2 and
        // reads fresh data — so skipping the record is correct (the table
        // degenerates to "promote eagerly, every time").
        PaRecord::NeedsInvalidate => t,
    }
}

fn record_release_if_srsp(
    m: &mut MemSystem,
    protocol: Protocol,
    cu: u32,
    addr: Addr,
    ticket: Option<u64>,
) {
    if protocol != Protocol::Srsp {
        return;
    }
    let Some(ticket) = ticket else { return };
    m.stats.lr_tbl_insertions += 1;
    if m.cu_mut(cu).lr_tbl.record(addr, ticket) {
        m.stats.lr_tbl_overflows += 1;
    }
}

// ----------------------------------------------------------------------
// cmp (global/device) scope — §2.2's heavyweight path, identical in all
// protocols.
// ----------------------------------------------------------------------

#[allow(clippy::too_many_arguments)]
fn cmp_scope_op(
    m: &mut MemSystem,
    cu: u32,
    addr: Addr,
    op: AtomicOp,
    order: MemOrder,
    operand: u32,
    cmp: u32,
    at: Cycle,
) -> SyncOutcome {
    let mut t = at;
    if order.releases() {
        m.stats.cmp_releases += 1;
        // Global release: every local update must reach the global sync
        // point (L2) — full cache-flush of the own L1.
        t = m.full_flush_l1(cu, t);
    }
    if order.acquires() {
        m.stats.cmp_acquires += 1;
        // Global acquire: all possibly-stale local data must be discarded.
        t = m.invalidate_l1(cu, t);
    }
    let (value, done) = m.l2_atomic(cu, addr, op, operand, cmp, t);
    charge_overhead(m, at, done);
    SyncOutcome { value, done }
}

// ----------------------------------------------------------------------
// sys scope (completeness)
// ----------------------------------------------------------------------

#[allow(clippy::too_many_arguments)]
fn sys_scope_op(
    m: &mut MemSystem,
    cu: u32,
    addr: Addr,
    op: AtomicOp,
    order: MemOrder,
    operand: u32,
    cmp: u32,
    at: Cycle,
) -> SyncOutcome {
    let mut t = at;
    if order.releases() {
        t = m.full_flush_l1(cu, t);
        t = m.full_flush_l2(t);
    }
    if order.acquires() {
        t = m.invalidate_l1(cu, t);
        t = m.invalidate_l2(t);
    }
    // The atomic itself executes at the memory controller on the backing
    // store (we route it through the L2 path after the L2 was flushed —
    // equivalent values, conservative timing).
    let (value, done) = m.l2_atomic(cu, addr, op, operand, cmp, t);
    charge_overhead(m, at, done);
    SyncOutcome { value, done }
}

// ----------------------------------------------------------------------
// Remote scope promotion (§3, §4)
// ----------------------------------------------------------------------

/// Perform a remote synchronization operation (`rem_acq`, `rem_rel`,
/// `rem_ar`) on `addr` from `cu`. `order` selects which: `Acquire` →
/// rem_acq, `Release` → rem_rel, `AcqRel` → rem_ar.
///
/// Panics if the protocol is [`Protocol::ScopedOnly`] — remote operations
/// require RSP hardware; scenarios without it must use cmp scope.
#[allow(clippy::too_many_arguments)]
pub fn remote_op(
    m: &mut MemSystem,
    protocol: Protocol,
    cu: u32,
    addr: Addr,
    op: AtomicOp,
    order: MemOrder,
    operand: u32,
    cmp: u32,
    at: Cycle,
) -> SyncOutcome {
    match order {
        MemOrder::Acquire => m.stats.remote_acquires += 1,
        MemOrder::Release => m.stats.remote_releases += 1,
        MemOrder::AcqRel => m.stats.remote_acqrels += 1,
        MemOrder::Relaxed => panic!("remote op requires acquire/release semantics"),
    }

    let out = match protocol {
        Protocol::ScopedOnly | Protocol::Hlrc => {
            panic!("remote scope promotion not supported by the {protocol:?} protocol")
        }
        Protocol::RspNaive => remote_op_naive(m, cu, addr, op, order, operand, cmp, at),
        Protocol::Srsp => remote_op_srsp(m, cu, addr, op, order, operand, cmp, at),
    };
    charge_overhead(m, at, out.done);
    out
}

/// Naive RSP (Orr et al.): promotion by flushing and invalidating **every**
/// L1 in the device — the scalability problem the paper fixes.
#[allow(clippy::too_many_arguments)]
fn remote_op_naive(
    m: &mut MemSystem,
    cu: u32,
    addr: Addr,
    op: AtomicOp,
    order: MemOrder,
    operand: u32,
    cmp: u32,
    at: Cycle,
) -> SyncOutcome {
    let line = line_of(addr);

    let mut t_ready = at;
    if order.acquires() {
        // rem_acq: promote the local sharer's past releases — since we
        // don't know *which* L1 is the local sharer, flush them all; and
        // since we don't know which lines are stale, invalidate them all.
        // The broadcast fans out through the L2.
        let t_req = m.xbar_hop(cu, at);
        let t_fan = m.l2_control_hop(line, t_req);
        let mut t_all = t_fan;
        for target in 0..m.num_cus() {
            if target == cu {
                continue;
            }
            let t_arrive = m.xbar_hop(target, t_fan);
            let t_inv = m.invalidate_l1(target, t_arrive); // drain + flash
            let t_ack = m.xbar_hop(target, t_inv);
            t_all = t_all.max(t_ack);
        }
        // Requester drains its own dirty data and invalidates (global
        // acquire semantics for itself).
        let t_own = m.invalidate_l1(cu, at);
        t_ready = t_all.max(t_own);
    }
    if order.releases() && !order.acquires() {
        // rem_rel: the remote sharer's updates must reach global scope
        // before the releasing store.
        t_ready = m.full_flush_l1(cu, at);
    } else if order.releases() {
        // rem_ar already flushed everything via the invalidates above.
    }

    // Lock the sync variable's line at the L2 for the duration (§4.2).
    m.lock_l2_line(line, t_ready);
    let (value, mut done) = m.l2_atomic(cu, addr, op, operand, cmp, t_ready);
    m.lock_l2_line(line, done);

    if order.releases() && !order.acquires() {
        // rem_rel: promote the local sharer's *next* acquire eagerly —
        // invalidate every other L1 so no stale copy can satisfy it.
        // (rem_ar already invalidated every L1 above; repeating the
        // broadcast would double-charge the combined operation.)
        let t_fan = m.l2_control_hop(line, done);
        let mut t_all = done;
        for target in 0..m.num_cus() {
            if target == cu {
                continue;
            }
            let t_arrive = m.xbar_hop(target, t_fan);
            let t_inv = m.invalidate_l1(target, t_arrive);
            let t_ack = m.xbar_hop(target, t_inv);
            t_all = t_all.max(t_ack);
        }
        done = t_all;
    }
    SyncOutcome { value, done }
}

/// sRSP (§4): selective-flush and selective-invalidate — only the local
/// sharer's L1 does heavy work, found via its LR-TBL; acquire promotion is
/// *deferred* through the PA-TBL instead of eager invalidation.
#[allow(clippy::too_many_arguments)]
fn remote_op_srsp(
    m: &mut MemSystem,
    cu: u32,
    addr: Addr,
    op: AtomicOp,
    order: MemOrder,
    operand: u32,
    cmp: u32,
    at: Cycle,
) -> SyncOutcome {
    let line = line_of(addr);

    let mut t_ready = at;
    if order.acquires() {
        // §4.2 optimization: if the local sharer runs on *this* CU the
        // LR-TBL hit is local and no broadcast is needed (same L1 ⇒ its
        // updates are already visible here). Only a *definite* entry may
        // take this shortcut: a sticky-overflowed table answers every
        // address conservatively (`Some(None)`), and skipping the
        // broadcast on that answer would leave the true local sharer's
        // sFIFO undrained — a stale read, not just a slow one.
        let own_hit = matches!(m.cu(cu).lr_tbl.lookup(addr), Some(Some(_)));
        let mut t_promote = at + 1; // own LR-TBL probe
        if !own_hit {
            m.stats.selective_flush_requests += 1;
            // Broadcast selective-flush(L) via the L2 to all other L1s.
            let t_req = m.xbar_hop(cu, at);
            let t_fan = m.l2_control_hop(line, t_req);
            let mut t_all = t_fan;
            for target in 0..m.num_cus() {
                if target == cu {
                    continue;
                }
                let t_arrive = m.xbar_hop(target, t_fan);
                // LR-TBL probe: one cycle.
                let lookup = m.cu(target).lr_tbl.lookup(addr);
                let t_done = match lookup {
                    None => {
                        // Definite miss: immediate ack (§4.2).
                        m.stats.selective_flush_nops += 1;
                        t_arrive + 1
                    }
                    Some(upto) => {
                        // Hit (or conservative overflow): drain the sFIFO
                        // up to the recorded ticket, then remember that the
                        // local sharer's next acquire of L must promote.
                        m.stats.selective_flush_drains += 1;
                        let t = m.flush_l1(target, upto, t_arrive + 1);
                        let t = record_pa(m, target, addr, t);
                        t
                    }
                };
                let t_ack = m.xbar_hop(target, t_done);
                t_all = t_all.max(t_ack);
            }
            t_promote = t_all;
        }
        // Requester performs a global acquire for itself: drain own dirty
        // lines and flash-invalidate (§4.2 steps 4–5).
        let t_own = m.invalidate_l1(cu, at);
        t_ready = t_promote.max(t_own);
    }
    if order.releases() && !order.acquires() {
        // §4.3 step 1–2: local cache-flush pushes the remote sharer's
        // updates to global scope.
        t_ready = m.full_flush_l1(cu, at);
    }

    // §4.2 step 6 / §4.3 step 3: the atomic completes at the L2, with the
    // line locked against intervening reads.
    m.lock_l2_line(line, t_ready);
    let (value, mut done) = m.l2_atomic(cu, addr, op, operand, cmp, t_ready);
    m.lock_l2_line(line, done);

    if order.releases() && !order.acquires() {
        // §4.3 step 4 (rem_rel): selective-invalidate — L1s record L in
        // their PA-TBL (one-cycle CAM insert); actual invalidation is
        // deferred to the local sharer's next wg-scope acquire of L.
        //
        // For rem_ar the arming already happened during the acquire
        // part's selective-flush, *at the LR-TBL-identified local
        // sharer(s) only* (§4.2's mechanism): a cache with no local
        // release on L holds no locally-produced state for it, so only
        // the identified sharer's next acquire needs promotion. This
        // keeps steal-heavy workloads (64 deque counters) from flooding
        // every PA-TBL in the device.
        m.stats.selective_inv_requests += 1;
        let t_fan = m.l2_control_hop(line, done);
        let mut t_all = done;
        for target in 0..m.num_cus() {
            if target == cu {
                continue;
            }
            let t_arrive = m.xbar_hop(target, t_fan);
            let t_rec = record_pa(m, target, addr, t_arrive + 1);
            let t_ack = m.xbar_hop(target, t_rec);
            t_all = t_all.max(t_ack);
        }
        done = t_all;
    }
    SyncOutcome { value, done }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeviceConfig;
    use crate::mem::MemSystem;

    fn sys(protocol: Protocol) -> (MemSystem, Protocol) {
        (MemSystem::new(DeviceConfig::small()), protocol)
    }

    const LOCK: Addr = 0x1000;
    const DATA: Addr = 0x2000;

    /// The paper's running example (§4): wg0 on CU0 is the local sharer of
    /// DATA guarded by LOCK; wg1 on CU1 steals it with remote ops.
    fn local_sharer_writes(m: &mut MemSystem, p: Protocol, t: Cycle) -> Cycle {
        // Update Y, then wg-scope release of the lock (atomic_ST_rel_wg).
        let t = m.l1_write(0, DATA, 4, 41, t);
        let out = sync_op(
            m, p, 0, LOCK, AtomicOp::Store, MemOrder::Release, Scope::Wg, 0, 0, t,
        );
        out.done
    }

    #[test]
    fn srsp_remote_acquire_sees_local_release() {
        let (mut m, p) = sys(Protocol::Srsp);
        let t = local_sharer_writes(&mut m, p, 0);
        // LR-TBL recorded the release.
        assert_eq!(m.cu(0).lr_tbl.len(), 1);

        // CU1: atomic_CAS_rem_acq_cmp(L, 0 -> 1).
        let out = remote_op(
            &mut m, p, 1, LOCK, AtomicOp::Cas, MemOrder::Acquire, 1, 0, t,
        );
        assert_eq!(out.value, 0, "CAS must see the released lock");
        // The promoted data write is now globally visible.
        let (v, _) = m.l1_read(1, DATA, 4, out.done);
        assert_eq!(v, 41, "remote acquire must pull the local sharer's data");
        // PA-TBL on CU0 now forces promotion of its next local acquire.
        assert!(m.cu(0).pa_tbl.needs_promotion(LOCK));
        assert_eq!(m.stats.selective_flush_drains, 1);
    }

    #[test]
    fn srsp_local_acquire_promoted_after_remote() {
        let (mut m, p) = sys(Protocol::Srsp);
        let t = local_sharer_writes(&mut m, p, 0);
        let out = remote_op(
            &mut m, p, 1, LOCK, AtomicOp::Cas, MemOrder::Acquire, 1, 0, t,
        );
        // CU0 tries to re-acquire locally: PA-TBL hit → promoted to L2 →
        // must observe the lock taken by CU1 (no stale-local false success).
        let out0 = sync_op(
            &mut m, p, 0, LOCK, AtomicOp::Cas, MemOrder::Acquire, Scope::Wg, 1, 0, out.done,
        );
        assert_eq!(out0.value, 1, "promoted acquire must see remote CAS");
        assert_eq!(m.stats.promoted_acquires, 1);
        // Tables cleared by the promotion's invalidate.
        assert!(!m.cu(0).pa_tbl.needs_promotion(LOCK));
    }

    #[test]
    fn srsp_remote_release_hands_data_back() {
        let (mut m, p) = sys(Protocol::Srsp);
        let t = local_sharer_writes(&mut m, p, 0);
        let acq = remote_op(&mut m, p, 1, LOCK, AtomicOp::Cas, MemOrder::Acquire, 1, 0, t);
        assert_eq!(acq.value, 0);
        // CU1 updates the data in its critical section, then rem_rel.
        let t = m.l1_write(1, DATA, 4, 99, acq.done);
        let rel = remote_op(&mut m, p, 1, LOCK, AtomicOp::Store, MemOrder::Release, 0, 0, t);
        // CU0 re-acquires locally: PA-TBL (set by selective-invalidate)
        // promotes it; the fresh data must be visible.
        let out0 = sync_op(
            &mut m, p, 0, LOCK, AtomicOp::Cas, MemOrder::Acquire, Scope::Wg, 1, 0, rel.done,
        );
        assert_eq!(out0.value, 0, "lock was released remotely");
        let (v, _) = m.l1_read(0, DATA, 4, out0.done);
        assert_eq!(v, 99, "thief's update must be visible after promotion");
    }

    #[test]
    fn naive_rsp_same_semantics() {
        let (mut m, p) = sys(Protocol::RspNaive);
        let t = local_sharer_writes(&mut m, p, 0);
        let acq = remote_op(&mut m, p, 1, LOCK, AtomicOp::Cas, MemOrder::Acquire, 1, 0, t);
        assert_eq!(acq.value, 0);
        let (v, t2) = m.l1_read(1, DATA, 4, acq.done);
        assert_eq!(v, 41);
        // Owner's local re-acquire: naive RSP invalidated every L1 during
        // rem_acq, so CU0 misses to L2 and sees the taken lock.
        let out0 = sync_op(
            &mut m, p, 0, LOCK, AtomicOp::Cas, MemOrder::Acquire, Scope::Wg, 1, 0, t2,
        );
        assert_eq!(out0.value, 1);
    }

    #[test]
    fn naive_invalidates_all_srsp_does_not() {
        // Warm unrelated data into every L1, then do one remote acquire.
        // Naive RSP destroys all that locality; sRSP keeps it.
        for proto in [Protocol::RspNaive, Protocol::Srsp] {
            let (mut m, p) = sys(proto);
            let mut t = local_sharer_writes(&mut m, p, 0);
            for cu in 0..4 {
                for i in 0..8u64 {
                    let (_, tt) = m.l1_read(cu, 0x9000 + cu as u64 * 0x1000 + i * 64, 4, t);
                    t = tt;
                }
            }
            let before_inv = m.stats.lines_invalidated;
            let _ = remote_op(&mut m, p, 1, LOCK, AtomicOp::Cas, MemOrder::Acquire, 1, 0, t);
            let invalidated = m.stats.lines_invalidated - before_inv;
            match proto {
                Protocol::RspNaive => assert!(
                    invalidated > 16,
                    "naive RSP must invalidate every L1 (got {invalidated})"
                ),
                Protocol::Srsp => assert!(
                    invalidated <= 16,
                    "sRSP must only invalidate the requester (got {invalidated})"
                ),
                _ => unreachable!(),
            }
        }
    }

    #[test]
    fn cmp_scope_is_protocol_independent_and_correct() {
        for proto in [Protocol::ScopedOnly, Protocol::RspNaive, Protocol::Srsp] {
            let (mut m, p) = sys(proto);
            // CU0 releases at cmp scope; CU2 acquires at cmp scope.
            let t = m.l1_write(0, DATA, 4, 7, 0);
            let rel = sync_op(
                &mut m, p, 0, LOCK, AtomicOp::Store, MemOrder::Release, Scope::Cmp, 1, 0, t,
            );
            let acq = sync_op(
                &mut m, p, 2, LOCK, AtomicOp::Load, MemOrder::Acquire, Scope::Cmp, 0, 0, rel.done,
            );
            assert_eq!(acq.value, 1);
            let (v, _) = m.l1_read(2, DATA, 4, acq.done);
            assert_eq!(v, 7, "cmp acquire/release pair must transfer data ({proto:?})");
        }
    }

    #[test]
    fn srsp_cheaper_than_naive_under_warm_caches() {
        let mut costs = Vec::new();
        for proto in [Protocol::RspNaive, Protocol::Srsp] {
            let (mut m, p) = sys(proto);
            let mut t = local_sharer_writes(&mut m, p, 0);
            // Dirty data on *other* CUs that naive RSP will pointlessly drain.
            for cu in 1..4 {
                for i in 0..12u64 {
                    t = m.l1_write(cu, 0x20000 + cu as u64 * 0x1000 + i * 64, 4, 1, t);
                }
            }
            let before = m.stats.sync_overhead_cycles;
            let _ = remote_op(&mut m, p, 1, LOCK, AtomicOp::Cas, MemOrder::Acquire, 1, 0, t);
            costs.push(m.stats.sync_overhead_cycles - before);
        }
        assert!(
            costs[1] < costs[0],
            "sRSP promotion ({}) must be cheaper than naive ({})",
            costs[1],
            costs[0]
        );
    }

    #[test]
    fn remote_op_without_own_lr_entry_broadcasts() {
        let (mut m, p) = sys(Protocol::Srsp);
        let t = local_sharer_writes(&mut m, p, 0);
        let _ = remote_op(&mut m, p, 1, LOCK, AtomicOp::Cas, MemOrder::Acquire, 1, 0, t);
        assert_eq!(m.stats.selective_flush_requests, 1);
        // 3 other CUs probed: one drain (CU0) + two nops (CU2, CU3).
        assert_eq!(m.stats.selective_flush_nops, 2);
        assert_eq!(m.stats.selective_flush_drains, 1);
    }

    #[test]
    fn same_cu_local_sharer_skips_broadcast() {
        let (mut m, p) = sys(Protocol::Srsp);
        // Local sharer on CU1; the remote op also issued from CU1.
        let t = m.l1_write(1, DATA, 4, 5, 0);
        let rel = sync_op(
            &mut m, p, 1, LOCK, AtomicOp::Store, MemOrder::Release, Scope::Wg, 0, 0, t,
        );
        let _ = remote_op(
            &mut m, p, 1, LOCK, AtomicOp::Cas, MemOrder::Acquire, 1, 0, rel.done,
        );
        assert_eq!(
            m.stats.selective_flush_requests, 0,
            "same-CU local sharer: §4.2 optimization skips the broadcast"
        );
    }

    const LOCK2: Addr = 0x3000;
    const DATA2: Addr = 0x4000;

    fn srsp_sys_with(lr: u32, pa: u32) -> MemSystem {
        MemSystem::new(DeviceConfig {
            lr_tbl_entries: lr,
            pa_tbl_entries: pa,
            ..DeviceConfig::small()
        })
    }

    #[test]
    fn lr_tbl_overflow_conservative_drain_stays_correct() {
        // Capacity 1: the second wg-scope release displaces the first;
        // the displaced address must still be found (conservative "drain
        // everything") by a remote acquire.
        let mut m = srsp_sys_with(1, 16);
        let p = Protocol::Srsp;
        let t = m.l1_write(0, DATA, 4, 41, 0);
        let t = sync_op(
            &mut m, p, 0, LOCK, AtomicOp::Store, MemOrder::Release, Scope::Wg, 1, 0, t,
        )
        .done;
        let t = m.l1_write(0, DATA2, 4, 42, t);
        let t = sync_op(
            &mut m, p, 0, LOCK2, AtomicOp::Store, MemOrder::Release, Scope::Wg, 1, 0, t,
        )
        .done;
        assert_eq!(m.stats.lr_tbl_overflows, 1, "capacity-1 table must overflow");
        assert!(m.cu(0).lr_tbl.has_overflowed());

        // LOCK carried the older ticket and was displaced; the remote
        // acquire must still drain CU0 and observe both the lock and the
        // guarded data.
        let out = remote_op(&mut m, p, 1, LOCK, AtomicOp::Cas, MemOrder::Acquire, 2, 1, t);
        assert_eq!(out.value, 1, "released lock must be visible");
        assert!(m.stats.selective_flush_drains >= 1, "overflow must drain, not nop");
        let (v, _) = m.l1_read(1, DATA, 4, out.done);
        assert_eq!(v, 41, "displaced entry must not lose the release's data");
    }

    #[test]
    fn requester_side_overflow_must_not_skip_the_broadcast() {
        // lr_tbl_entries = 0: every table is sticky-overflowed from the
        // first release. The requester's own conservative `Some(None)`
        // answer must NOT be mistaken for "the local sharer is me" — the
        // true sharer (CU0) still has the lock value in its sFIFO, and
        // skipping the selective-flush broadcast would read it stale.
        let mut m = srsp_sys_with(0, 16);
        let p = Protocol::Srsp;
        let t = m.l1_write(0, DATA, 4, 41, 0);
        let t = sync_op(
            &mut m, p, 0, LOCK, AtomicOp::Store, MemOrder::Release, Scope::Wg, 1, 0, t,
        )
        .done;
        // Overflow the *requester's* table too (a release on an unrelated
        // variable).
        let t = m.l1_write(1, DATA2, 4, 9, t);
        let t = sync_op(
            &mut m, p, 1, LOCK2, AtomicOp::Store, MemOrder::Release, Scope::Wg, 1, 0, t,
        )
        .done;
        assert!(m.cu(1).lr_tbl.has_overflowed());
        assert!(m.stats.lr_tbl_overflows >= 2);

        let out = remote_op(&mut m, p, 1, LOCK, AtomicOp::Cas, MemOrder::Acquire, 2, 1, t);
        assert_eq!(
            m.stats.selective_flush_requests, 1,
            "conservative own-table answer must still broadcast"
        );
        assert_eq!(out.value, 1, "CAS must see CU0's released lock");
        let (v, _) = m.l1_read(1, DATA, 4, out.done);
        assert_eq!(v, 41, "CU0's sFIFO must have been drained");
    }

    #[test]
    fn pa_tbl_overflow_eager_invalidate_keeps_correctness() {
        // Capacity 1: arming a second address at a full table forces the
        // eager local invalidate (discharging the first obligation) and
        // then records the second. Both locks' data must stay visible.
        let mut m = srsp_sys_with(16, 1);
        let p = Protocol::Srsp;
        let t = m.l1_write(1, DATA, 4, 7, 0);
        let t = remote_op(&mut m, p, 1, LOCK, AtomicOp::Store, MemOrder::Release, 1, 0, t).done;
        let t = m.l1_write(1, DATA2, 4, 9, t);
        let t = remote_op(&mut m, p, 1, LOCK2, AtomicOp::Store, MemOrder::Release, 1, 0, t).done;
        // Each of the 3 other CUs had LOCK armed and overflowed on LOCK2.
        assert_eq!(m.stats.pa_tbl_overflows, 3);
        assert!(m.cu(0).pa_tbl.needs_promotion(LOCK2));
        assert!(
            !m.cu(0).pa_tbl.needs_promotion(LOCK),
            "eager invalidate discharged the first obligation"
        );

        // LOCK2: promoted via the PA-TBL hit.
        let out = sync_op(
            &mut m, p, 0, LOCK2, AtomicOp::Load, MemOrder::Acquire, Scope::Wg, 0, 0, t,
        );
        assert_eq!(out.value, 1);
        let (v, t) = m.l1_read(0, DATA2, 4, out.done);
        assert_eq!(v, 9);
        // LOCK: obligation was discharged by the eager invalidate — the
        // acquire stays local but misses to the L2 and reads fresh.
        let out = sync_op(
            &mut m, p, 0, LOCK, AtomicOp::Load, MemOrder::Acquire, Scope::Wg, 0, 0, t,
        );
        assert_eq!(out.value, 1);
        let (v, _) = m.l1_read(0, DATA, 4, out.done);
        assert_eq!(v, 7);
    }

    #[test]
    fn zero_capacity_pa_tbl_promotes_eagerly() {
        // pa_tbl_entries = 0: nothing can be deferred; every arming
        // degenerates to an immediate invalidate at the target. Must not
        // panic, must count overflows, must stay correct.
        let mut m = srsp_sys_with(16, 0);
        let p = Protocol::Srsp;
        let t = m.l1_write(1, DATA, 4, 5, 0);
        let t = remote_op(&mut m, p, 1, LOCK, AtomicOp::Store, MemOrder::Release, 1, 0, t).done;
        assert_eq!(m.stats.pa_tbl_overflows, 3, "one per non-requesting CU");
        assert!(m.cu(0).pa_tbl.is_empty());
        let out = sync_op(
            &mut m, p, 0, LOCK, AtomicOp::Load, MemOrder::Acquire, Scope::Wg, 0, 0, t,
        );
        assert_eq!(out.value, 1, "eager invalidate must publish the release");
        let (v, _) = m.l1_read(0, DATA, 4, out.done);
        assert_eq!(v, 5);
    }

    #[test]
    #[should_panic(expected = "not supported")]
    fn scoped_only_rejects_remote_ops() {
        let (mut m, p) = sys(Protocol::ScopedOnly);
        let _ = remote_op(&mut m, p, 0, LOCK, AtomicOp::Cas, MemOrder::Acquire, 1, 0, 0);
    }

    #[test]
    fn rem_ar_full_fence_semantics() {
        for proto in [Protocol::RspNaive, Protocol::Srsp] {
            let (mut m, p) = sys(proto);
            let t = local_sharer_writes(&mut m, p, 0);
            // rem_ar: fetch-add on a counter with full fence.
            let t2 = m.l1_write(1, DATA + 64, 4, 11, t);
            let out = remote_op(
                &mut m, p, 1, LOCK, AtomicOp::Add, MemOrder::AcqRel, 1, 0, t2,
            );
            assert_eq!(out.value, 0);
            // Both directions visible: CU1 saw CU0's data...
            let (v, t3) = m.l1_read(1, DATA, 4, out.done);
            assert_eq!(v, 41);
            // ...and CU0's next promoted acquire sees CU1's write.
            let out0 = sync_op(
                &mut m, p, 0, LOCK, AtomicOp::Load, MemOrder::Acquire, Scope::Wg, 0, 0, t3,
            );
            assert_eq!(out0.value, 1);
            let (v2, _) = m.l1_read(0, DATA + 64, 4, out0.done);
            assert_eq!(v2, 11, "{proto:?}: rem_ar must publish the thief's writes");
        }
    }
}
