//! sRSP (§4, the paper's contribution): selective-flush and
//! selective-invalidate — only the local sharer's L1 does heavy work,
//! found via its LR-TBL; acquire promotion is *deferred* through the
//! PA-TBL instead of eager invalidation.
//!
//! | op             | behavior                                          |
//! |----------------|---------------------------------------------------|
//! | wg acquire     | PA-TBL check → maybe promote (§4.4)               |
//! | wg release     | + LR-TBL record (§4.1)                            |
//! | remote acquire | selective-flush bcast (§4.2) + L2 op              |
//! | remote release | flush own + L2 op + sel-inv bcast (§4.3)          |
//! | remote acq+rel | both of the above                                 |

use super::ops::{self, SyncOp, SyncOutcome};
use super::protocol::SyncProtocol;
use crate::mem::{line_of, MemSystem};
use crate::params::ParamSpec;
use crate::sim::TraceKind;

/// The table-capacity parameters of the sRSP family. The defaults mirror
/// Table 1; an explicit `--proto-param` wins over the device config's
/// `lr_tbl_entries`/`pa_tbl_entries` fields.
pub const TABLE_PARAMS: [ParamSpec; 2] = [
    ParamSpec {
        key: "lr_tbl_entries",
        default: 16.0,
        help: "LR-TBL capacity; 0 = sticky-overflow from the first release",
    },
    ParamSpec {
        key: "pa_tbl_entries",
        default: 16.0,
        help: "PA-TBL capacity; 0 = promote eagerly, every time",
    },
];

/// Registry entry for sRSP.
pub struct Srsp;

impl SyncProtocol for Srsp {
    fn name(&self) -> &'static str {
        "srsp"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["selective"]
    }

    fn summary(&self) -> &'static str {
        "scalable RSP: LR-TBL selective flush, PA-TBL deferred invalidation"
    }

    fn params(&self) -> &'static [ParamSpec] {
        &TABLE_PARAMS
    }

    fn supports_remote(&self) -> bool {
        true
    }

    fn wg_op(&self, m: &mut MemSystem, s: &SyncOp) -> SyncOutcome {
        wg(m, s)
    }

    fn remote_op(&self, m: &mut MemSystem, s: &SyncOp) -> SyncOutcome {
        remote(m, s)
    }
}

/// wg-scope op with the sRSP table machinery, exposed as a free function
/// so the adaptive protocol can reuse it.
pub fn wg(m: &mut MemSystem, s: &SyncOp) -> SyncOutcome {
    // §4.4: a wg-scope acquire first consults the PA-TBL; a hit promotes
    // it to global scope (full L1 invalidate + atomic at L2).
    if s.order.acquires() {
        // The PA-TBL lookup itself costs one cycle (CAM probe).
        let t = s.at + 1;
        if m.cu(s.cu).pa_tbl.needs_promotion(s.addr) {
            m.stats.promoted_acquires += 1;
            m.trace.emit(s.at, s.cu, TraceKind::Promotion, s.addr, 0);
            let t = m.invalidate_l1(s.cu, t); // also clears LR-TBL + PA-TBL
            let (value, done) = m.l2_atomic(s.cu, s.addr, s.op, s.operand, s.cmp, t);
            ops::charge_overhead(m, s.at, done);
            // A promoted acquire that also releases (AcqRel) performed its
            // write at the L2 already; nothing further needed.
            return SyncOutcome { value, done };
        }
        m.stats.local_acquires += 1;
        m.trace.emit(s.at, s.cu, TraceKind::LocalAcquire, s.addr, 0);
        let (value, ticket, done) = m.l1_atomic(s.cu, s.addr, s.op, s.operand, s.cmp, t);
        if s.op.writes_given(value, s.operand, s.cmp) {
            ops::record_lr_release(m, s.cu, s.addr, Some(ticket), s.at);
        }
        ops::charge_overhead(m, s.at, done);
        return SyncOutcome { value, done };
    }
    // Plain wg-scope atomic with §4.1 LR-TBL recording of sync writes.
    ops::wg_plain(m, s, true)
}

/// The selective remote promotion (§4.2/§4.3), exposed as a free
/// function so the adaptive protocol can delegate to it.
pub fn remote(m: &mut MemSystem, s: &SyncOp) -> SyncOutcome {
    let line = line_of(s.addr);

    let mut t_ready = s.at;
    if s.order.acquires() {
        // §4.2 optimization: if the local sharer runs on *this* CU the
        // LR-TBL hit is local and no broadcast is needed (same L1 ⇒ its
        // updates are already visible here). Only a *definite* entry may
        // take this shortcut: a sticky-overflowed table answers every
        // address conservatively (`Some(None)`), and skipping the
        // broadcast on that answer would leave the true local sharer's
        // sFIFO undrained — a stale read, not just a slow one.
        let own_hit = matches!(m.cu(s.cu).lr_tbl.lookup(s.addr), Some(Some(_)));
        let mut t_promote = s.at + 1; // own LR-TBL probe
        if !own_hit {
            m.stats.selective_flush_requests += 1;
            m.trace.emit(s.at, s.cu, TraceKind::SelFlushRequest, s.addr, 0);
            // Broadcast selective-flush(L) via the L2 to all other L1s.
            let t_req = m.xbar_hop(s.cu, s.at);
            let t_fan = m.l2_control_hop(line, t_req);
            let mut t_all = t_fan;
            for target in 0..m.num_cus() {
                if target == s.cu {
                    continue;
                }
                let t_arrive = m.xbar_hop(target, t_fan);
                // LR-TBL probe: one cycle.
                let lookup = m.cu(target).lr_tbl.lookup(s.addr);
                let t_done = match lookup {
                    None => {
                        // Definite miss: immediate ack (§4.2).
                        m.stats.selective_flush_nops += 1;
                        m.trace.emit(t_arrive, target, TraceKind::SelFlushNop, s.addr, 0);
                        t_arrive + 1
                    }
                    Some(upto) => {
                        // Hit (or conservative overflow): drain the sFIFO
                        // up to the recorded ticket, then remember that the
                        // local sharer's next acquire of L must promote.
                        m.stats.selective_flush_drains += 1;
                        m.trace.emit(
                            t_arrive,
                            target,
                            TraceKind::SelFlushDrain,
                            s.addr,
                            upto.unwrap_or(u64::MAX),
                        );
                        let t = m.flush_l1(target, upto, t_arrive + 1);
                        ops::record_pa(m, target, s.addr, t)
                    }
                };
                let t_ack = m.xbar_hop(target, t_done);
                t_all = t_all.max(t_ack);
            }
            t_promote = t_all;
        }
        // Requester performs a global acquire for itself: drain own dirty
        // lines and flash-invalidate (§4.2 steps 4–5).
        let t_own = m.invalidate_l1(s.cu, s.at);
        t_ready = t_promote.max(t_own);
    }
    if s.order.releases() && !s.order.acquires() {
        // §4.3 step 1–2: local cache-flush pushes the remote sharer's
        // updates to global scope.
        t_ready = m.full_flush_l1(s.cu, s.at);
    }

    // §4.2 step 6 / §4.3 step 3: the atomic completes at the L2, with the
    // line locked against intervening reads.
    m.lock_l2_line(line, t_ready);
    let (value, mut done) = m.l2_atomic(s.cu, s.addr, s.op, s.operand, s.cmp, t_ready);
    m.lock_l2_line(line, done);

    if s.order.releases() && !s.order.acquires() {
        // §4.3 step 4 (rem_rel): selective-invalidate — L1s record L in
        // their PA-TBL (one-cycle CAM insert); actual invalidation is
        // deferred to the local sharer's next wg-scope acquire of L.
        //
        // For rem_ar the arming already happened during the acquire
        // part's selective-flush, *at the LR-TBL-identified local
        // sharer(s) only* (§4.2's mechanism): a cache with no local
        // release on L holds no locally-produced state for it, so only
        // the identified sharer's next acquire needs promotion. This
        // keeps steal-heavy workloads (64 deque counters) from flooding
        // every PA-TBL in the device.
        m.stats.selective_inv_requests += 1;
        m.trace.emit(done, s.cu, TraceKind::SelInvRequest, s.addr, 0);
        let t_fan = m.l2_control_hop(line, done);
        let mut t_all = done;
        for target in 0..m.num_cus() {
            if target == s.cu {
                continue;
            }
            let t_arrive = m.xbar_hop(target, t_fan);
            let t_rec = ops::record_pa(m, target, s.addr, t_arrive + 1);
            let t_ack = m.xbar_hop(target, t_rec);
            t_all = t_all.max(t_ack);
        }
        done = t_all;
    }
    SyncOutcome { value, done }
}
