//! The scoped-only baseline protocol: plain OpenCL-style scoped
//! acquire/release with **no** remote-scope promotion. Work-stealing
//! scenarios that need cross-CU claims must use cmp scope (the paper's
//! Baseline and Steal-only configurations).
//!
//! This is the smallest [`SyncProtocol`] implementation — the template
//! for a new registry entry.

use super::ops::{self, SyncOp, SyncOutcome};
use super::protocol::SyncProtocol;
use crate::mem::MemSystem;

/// Registry entry for the scoped-only baseline.
pub struct ScopedOnly;

impl SyncProtocol for ScopedOnly {
    fn name(&self) -> &'static str {
        "scoped"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["scoped-only", "baseline-protocol"]
    }

    fn summary(&self) -> &'static str {
        "scoped acquire/release only; no remote-scope promotion"
    }

    fn wg_op(&self, m: &mut MemSystem, s: &SyncOp) -> SyncOutcome {
        // Plain wg-scope atomic at the L1; no table bookkeeping.
        ops::wg_plain(m, s, false)
    }
}
