//! Scoped synchronization semantics and the pluggable protocol registry.
//!
//! * [`scope`] — OpenCL-style scopes and memory orderings, atomic ops.
//! * [`tables`] — the paper's new per-L1 hardware: **LR-TBL** (local release
//!   table: sync address → sFIFO ticket of the last wg-scope release) and
//!   **PA-TBL** (promoted-acquire table: addresses whose next wg-scope
//!   acquire must be promoted to global scope).
//! * [`protocol`] — the [`SyncProtocol`] trait and the static
//!   [`PROTOCOLS`] registry every layer resolves protocols through
//!   (`srsp list-protocols`, `--protocol <name>`, `--proto-param k=v`).
//! * [`ops`] — the protocol-independent scoped-op core (cmp/sys scope,
//!   the plain wg-scope atomic, table bookkeeping, overhead accounting).
//! * per-protocol modules, one file each: [`scoped`], [`rsp_naive`],
//!   [`srsp`], [`hlrc`], [`srsp_adaptive`].
//! * [`engine`] — thin dispatch from operation requests to the
//!   registered protocol hooks.

pub mod engine;
pub mod hlrc;
pub mod ops;
pub mod protocol;
pub mod rsp_naive;
pub mod scope;
pub mod scoped;
pub mod srsp;
pub mod srsp_adaptive;
pub mod tables;

pub use engine::{remote_op, sync_op, SyncOutcome};
pub use ops::SyncOp;
pub use protocol::{Protocol, SyncProtocol, PROTOCOLS};
pub use scope::{AtomicOp, MemOrder, Scope};
pub use tables::{LrTbl, PaTbl};
