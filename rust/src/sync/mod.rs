//! Scoped synchronization semantics and the three protocol engines.
//!
//! * [`scope`] — OpenCL-style scopes and memory orderings, atomic ops.
//! * [`tables`] — the paper's new per-L1 hardware: **LR-TBL** (local release
//!   table: sync address → sFIFO ticket of the last wg-scope release) and
//!   **PA-TBL** (promoted-acquire table: addresses whose next wg-scope
//!   acquire must be promoted to global scope).
//! * [`engine`] — the orchestration of scoped / remote operations over the
//!   [`MemSystem`](crate::mem::MemSystem) primitives, per
//!   [`Protocol`](crate::config::Protocol):
//!   global-scope baseline, naive RSP (flush/invalidate every L1) and sRSP
//!   (selective-flush / selective-invalidate).

pub mod engine;
pub mod scope;
pub mod tables;

pub use engine::{remote_op, sync_op, SyncOutcome};
pub use scope::{AtomicOp, MemOrder, Scope};
pub use tables::{LrTbl, PaTbl};
