//! Bench: simulator hot-path throughput (host-side performance of the
//! simulator itself, the §Perf target for Layer 3).
//!
//! Thin wrapper over the shared measurement core in
//! [`srsp::harness::bench`] — the same cells, statistics, and versioned
//! JSON schema as `srsp bench hotpath`. The workloads and scenarios come
//! from the registries by name (no hard-coded `Scenario` consts here);
//! human lines go to stderr, the `BENCH_*.json` document to stdout.
//!
//! Flags: `--size tiny|paper`, `--cus N`, `--repeats N`, `--warmup N`,
//! `--compare-reference` (also time the pre-decode reference interpreter
//! and record the decoded-path speedup, asserting identical simulated
//! results).

mod bench_common;

use srsp::harness::bench::{run_bench, BenchOpts};

fn main() {
    let (cfg, size) = bench_common::parse_args();
    let mut opts = BenchOpts::hotpath(size);
    if let Some(n) = bench_common::parse_flag_u32("--repeats") {
        opts.repeats = n.max(1);
    }
    if let Some(n) = bench_common::parse_flag_u32("--warmup") {
        opts.warmup = n;
    }
    opts.compare_reference = std::env::args().any(|a| a == "--compare-reference");
    let report = run_bench(&cfg, &opts);
    eprint!("{}", report.render_human());
    print!("{}", report.to_json());
}
