//! Bench: simulator hot-path throughput (host-side performance of the
//! simulator itself, the §Perf target for Layer 3). Reports simulated
//! cycles per wall second and events/instructions per second for a
//! PageRank round on the Table-1 device.

use srsp::config::Scenario;
use srsp::harness::figures::run_one;
use srsp::harness::presets::{WorkloadPreset, WorkloadSize};
use std::time::Instant;

fn main() {
    let (cfg, size) = {
        // default: paper scale
        let mut c = srsp::config::DeviceConfig::default();
        let mut s = WorkloadSize::Paper;
        if std::env::args().any(|a| a == "tiny") {
            c.num_cus = 8;
            s = WorkloadSize::Tiny;
        }
        (c, s)
    };
    for scenario in [Scenario::SCOPE_ONLY, Scenario::SRSP, Scenario::RSP] {
        let preset = WorkloadPreset::new(srsp::workload::registry::PRK, size);
        let t0 = Instant::now();
        let r = run_one(&cfg, &preset, scenario);
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "{:>6}: wall {:>7.3}s  sim-cycles {:>10}  Mcycles/s {:>8.2}  Minstr/s {:>8.2}",
            scenario.name(),
            dt,
            r.stats.cycles,
            r.stats.cycles as f64 / dt / 1e6,
            r.stats.instructions as f64 / dt / 1e6,
        );
    }
}
