//! Bench: print the Table-1 simulation parameters (and validate them).

use srsp::config::DeviceConfig;

fn main() {
    let cfg = DeviceConfig::default();
    cfg.validate().expect("Table-1 defaults must validate");
    println!("Table 1 — simulation parameters\n{}", cfg.table1());
    assert_eq!(cfg.num_cus, 64);
    assert_eq!(cfg.l1_sets(), 16);
    assert_eq!(cfg.l2_sets(), 512);
}
