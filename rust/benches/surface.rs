//! Bench: the protocol × remote-ratio × CU-count **surface** — the
//! paper's headline Fig. 4 number (~29% average speedup at 64 CUs) is a
//! single slice of this surface; the crossover between naive RSP and
//! sRSP shifts jointly with contention asymmetry (`r`) and device size,
//! so the claim worth regenerating is the whole composed grid.
//!
//! Expected shape: at the local-sharing corner (`r = 0`, small device)
//! the three protocols tie; toward the remote-heavy large-device corner
//! naive RSP's flush-all promotion cost grows with the CU count while
//! sRSP's selectivity keeps it bounded — the sRSP advantage must widen
//! along both axes.

mod bench_common;
use srsp::coordinator::{axis, Runner, SweepPlan};
use srsp::harness::figures::sweep_speedup_rows;
use srsp::harness::report::format_table;

fn main() {
    let (cfg, size) = bench_common::parse_args();
    let runner = Runner {
        validate: true,
        ..Runner::new(cfg, size, Runner::default_jobs())
    };
    let plan = SweepPlan::new(
        srsp::workload::registry::STRESS,
        &[axis::REMOTE_RATIO, axis::CU_COUNT],
    )
    .expect("stress declares remote_ratio")
    .with_points(axis::REMOTE_RATIO, vec![0.0, 0.2, 0.8])
    .expect("valid ratio points")
    .with_points(axis::CU_COUNT, vec![8.0, 16.0, 32.0])
    .expect("valid cu-count points");
    let results =
        bench_common::timed("remote-ratio × cu-count surface", || runner.run_sweep(&plan));

    assert!(
        results.iter().all(|c| c.validated == Some(true)),
        "every protocol must pass the stress oracle at every grid point"
    );
    let rows = sweep_speedup_rows(&plan, &results);
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.coords[0].1.to_string(),
                r.coords[1].1.to_string(),
                r.steal_cycles.to_string(),
                format!("{:.3}", r.rsp_speedup),
                format!("{:.3}", r.srsp_speedup),
            ]
        })
        .collect();
    let header = [
        "r".into(),
        "CUs".into(),
        "steal cycles".into(),
        "rsp ×".into(),
        "srsp ×".into(),
    ];
    println!(
        "Surface — STRESS — protocol × r × CU-count, speedup vs global-scope stealing\n{}",
        format_table(&header, &body)
    );

    // The qualitative surface claim: sRSP's edge over naive RSP at the
    // remote-heavy end must grow with device size.
    let edge = |r: f64, cus: f64| {
        let row = rows
            .iter()
            .find(|x| x.coords[0].1 == r && x.coords[1].1 == cus)
            .expect("grid covers every combo");
        row.srsp_speedup / row.rsp_speedup
    };
    assert!(
        edge(0.8, 32.0) > edge(0.8, 8.0),
        "sRSP's advantage at r=0.8 must widen with CU count ({:.3} vs {:.3})",
        edge(0.8, 32.0),
        edge(0.8, 8.0)
    );
}
