//! Bench: regenerate the paper's Fig. 5 — L2 accesses (bandwidth proxy)
//! relative to Baseline (paper: Scope lowest; sRSP well below RSP).

mod bench_common;
use srsp::harness::figures::{fig5_l2, run_matrix_jobs};

fn main() {
    let (cfg, size) = bench_common::parse_args();
    // jobs=1: wall time measures simulator cost, not host parallelism.
    let results = bench_common::timed("fig5 matrix", || run_matrix_jobs(&cfg, size, 1));
    let table = fig5_l2(&results);
    println!("{}", table.render());
    use srsp::config::Scenario;
    assert!(
        table.geomean(Scenario::SRSP) < table.geomean(Scenario::RSP),
        "sRSP must generate less L2 traffic than naive RSP"
    );
    assert!(
        table.geomean(Scenario::SCOPE_ONLY) < 1.0,
        "local scope must reduce L2 traffic below global-scope Baseline"
    );
}
