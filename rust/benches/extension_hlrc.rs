//! Extension bench: sRSP vs hLRC (the paper's §6 closest related work)
//! on the three workloads at 64 CUs.
//!
//! Expected shape per the paper's discussion: hLRC is also scalable
//! (transfer cost is O(1) caches, like sRSP), but its lock transfers
//! ping-pong whole-cache flush/invalidate pairs and its registry burns a
//! line per sync variable — so sRSP should hold an edge where steals are
//! frequent, while both beat naive RSP comfortably.

mod bench_common;
use srsp::config::Scenario;
use srsp::coordinator::classic_apps;
use srsp::harness::figures::run_one;
use srsp::harness::presets::WorkloadPreset;
use srsp::harness::report::format_table;

fn main() {
    let (cfg, size) = bench_common::parse_args();
    let mut rows = Vec::new();
    for app in classic_apps() {
        let preset = WorkloadPreset::new(app, size);
        let base = run_one(&cfg, &preset, Scenario::BASELINE).stats.cycles as f64;
        let mut row = vec![app.display().to_string()];
        for s in [Scenario::RSP, Scenario::SRSP, Scenario::HLRC] {
            let r = bench_common::timed(&format!("{}/{}", app.display(), s.name()), || {
                run_one(&cfg, &preset, s)
            });
            row.push(format!("{:.3}", base / r.stats.cycles as f64));
        }
        rows.push(row);
    }
    println!(
        "Extension — speedup vs Baseline: naive RSP vs sRSP vs hLRC\n{}",
        format_table(
            &["app".into(), "rsp".into(), "srsp".into(), "hlrc".into()],
            &rows
        )
    );
}
