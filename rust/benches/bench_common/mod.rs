//! Shared helpers for the hand-rolled bench harness (criterion is not
//! available offline; each bench is a `harness = false` binary that
//! regenerates one of the paper's tables/figures and reports wall time).

use std::time::Instant;

// Each bench binary compiles its own copy of this module, so helpers a
// given bench does not use are expected dead code.
#[allow(dead_code)]
pub fn timed<T>(label: &str, f: impl FnOnce() -> T) -> T {
    let t0 = Instant::now();
    let out = f();
    eprintln!("[bench] {label}: {:.2?}", t0.elapsed());
    out
}

/// `--<flag> N`: parse a u32 flag value if present.
#[allow(dead_code)]
pub fn parse_flag_u32(flag: &str) -> Option<u32> {
    let args: Vec<String> = std::env::args().collect();
    let i = args.iter().position(|a| a == flag)?;
    let v = args
        .get(i + 1)
        .unwrap_or_else(|| panic!("{flag} needs a value"));
    Some(v.parse().unwrap_or_else(|e| panic!("{flag}: {e}")))
}

/// `--size tiny` (CI smoke) vs default paper scale; `--cus N` override.
#[allow(dead_code)]
pub fn parse_args() -> (srsp::config::DeviceConfig, srsp::harness::WorkloadSize) {
    let mut cfg = srsp::config::DeviceConfig::default();
    let mut size = srsp::harness::WorkloadSize::Paper;
    let args: Vec<String> = std::env::args().collect();
    let mut i = 1;
    while i < args.len() {
        match args[i].as_str() {
            "--size" if args.get(i + 1).map(|s| s.as_str()) == Some("tiny") => {
                size = srsp::harness::WorkloadSize::Tiny;
                cfg.num_cus = 8;
                i += 1;
            }
            "--cus" => {
                cfg.num_cus = args[i + 1].parse().expect("--cus");
                i += 1;
            }
            _ => {}
        }
        i += 1;
    }
    (cfg, size)
}
