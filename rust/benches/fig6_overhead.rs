//! Bench: regenerate the paper's Fig. 6 — synchronization overhead of RSP
//! and sRSP relative to RSP (RSP = 1.0; paper: sRSP much lower).

mod bench_common;
use srsp::harness::figures::{fig6_overhead, run_matrix_jobs};

fn main() {
    let (cfg, size) = bench_common::parse_args();
    // jobs=1: wall time measures simulator cost, not host parallelism.
    let results = bench_common::timed("fig6 matrix", || run_matrix_jobs(&cfg, size, 1));
    let table = fig6_overhead(&results);
    println!("{}", table.render());
    use srsp::config::Scenario;
    assert!(
        table.geomean(Scenario::SRSP) < 1.0,
        "selective promotion must cost less than naive all-L1 promotion"
    );
}
