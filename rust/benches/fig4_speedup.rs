//! Bench: regenerate the paper's Fig. 4 — per-app speedup of the five
//! scenarios relative to Baseline on the 64-CU Table-1 device, plus the
//! geomean (paper: sRSP ≈ +29% geomean, best on SSSP; RSP loses its
//! gains; Scope-only and sRSP are the winners).

mod bench_common;
use srsp::harness::figures::{fig4_speedup, run_matrix_jobs};

fn main() {
    let (cfg, size) = bench_common::parse_args();
    // jobs=1: the reported wall time measures simulator cost, not host
    // parallelism (use the CLI's --jobs for parallel regeneration).
    let results = bench_common::timed("fig4 matrix", || run_matrix_jobs(&cfg, size, 1));
    let table = fig4_speedup(&results);
    println!("{}", table.render());
    // Shape assertions (the paper's qualitative claims).
    use srsp::config::Scenario;
    assert!(
        table.geomean(Scenario::SRSP) > table.geomean(Scenario::RSP),
        "sRSP must outperform naive RSP"
    );
    assert!(
        table.geomean(Scenario::SRSP) > 1.1,
        "sRSP must clearly beat the Baseline"
    );
    println!(
        "sRSP geomean speedup: {:.3} (paper: ~1.29); RSP: {:.3}",
        table.geomean(Scenario::SRSP),
        table.geomean(Scenario::RSP)
    );
}
