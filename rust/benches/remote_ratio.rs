//! Bench: the remote-ratio crossover curve — the asymmetry axis the
//! paper's argument turns on, swept on the synthetic stress family.
//!
//! Expected shape: at `r = 0` every protocol degenerates to wg-scope
//! fast paths and the three tie; as `r` grows, RspNaive's flush-all
//! promotion cost scales with the device and collapses, while sRSP's
//! LR-TBL/PA-TBL selectivity keeps the promotion cost bounded by the hot
//! owner's sFIFO — the gap widens with `r` and with CU count.

mod bench_common;
use srsp::coordinator::{Runner, RATIO_POINTS};
use srsp::harness::report::format_table;

fn main() {
    let (cfg, size) = bench_common::parse_args();
    let runner = Runner {
        validate: true,
        ..Runner::new(cfg, size, Runner::default_jobs())
    };
    let results = bench_common::timed("remote-ratio sweep", || {
        runner.run_remote_ratio_sweep(srsp::workload::registry::STRESS, &RATIO_POINTS)
    });

    let cycles = |scenario, r| {
        results
            .iter()
            .find(|c| c.cell.scenario == scenario && c.remote_ratio == Some(r))
            .map(|c| c.result.stats.cycles as f64)
            .expect("grid covers every point")
    };
    use srsp::config::Scenario;
    let mut rows = Vec::new();
    for &r in &RATIO_POINTS {
        let base = cycles(Scenario::STEAL_ONLY, r);
        rows.push(vec![
            r.to_string(),
            format!("{}", base as u64),
            format!("{:.3}", base / cycles(Scenario::RSP, r)),
            format!("{:.3}", base / cycles(Scenario::SRSP, r)),
        ]);
    }
    assert!(
        results.iter().all(|c| c.validated == Some(true)),
        "every protocol must pass the stress oracle at every r"
    );
    let header = ["r".into(), "steal cycles".into(), "rsp ×".into(), "srsp ×".into()];
    println!(
        "Remote-ratio crossover — STRESS — speedup vs global-scope stealing\n{}",
        format_table(&header, &rows)
    );
}
