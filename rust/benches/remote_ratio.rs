//! Bench: the remote-ratio crossover curve — the asymmetry axis the
//! paper's argument turns on, swept on the synthetic stress family.
//!
//! Expected shape: at `r = 0` every protocol degenerates to wg-scope
//! fast paths and the three tie; as `r` grows, RspNaive's flush-all
//! promotion cost scales with the device and collapses, while sRSP's
//! LR-TBL/PA-TBL selectivity keeps the promotion cost bounded by the hot
//! owner's sFIFO — the gap widens with `r` and with CU count.

mod bench_common;
use srsp::coordinator::{axis, Runner, SweepPlan};
use srsp::harness::figures::sweep_speedup_rows;
use srsp::harness::report::format_table;

fn main() {
    let (cfg, size) = bench_common::parse_args();
    let runner = Runner {
        validate: true,
        ..Runner::new(cfg, size, Runner::default_jobs())
    };
    let plan = SweepPlan::new(srsp::workload::registry::STRESS, &[axis::REMOTE_RATIO])
        .expect("stress declares remote_ratio");
    let results = bench_common::timed("remote-ratio sweep", || runner.run_sweep(&plan));

    assert!(
        results.iter().all(|c| c.validated == Some(true)),
        "every protocol must pass the stress oracle at every r"
    );
    let rows: Vec<Vec<String>> = sweep_speedup_rows(&plan, &results)
        .iter()
        .map(|r| {
            vec![
                r.coords[0].1.to_string(),
                r.steal_cycles.to_string(),
                format!("{:.3}", r.rsp_speedup),
                format!("{:.3}", r.srsp_speedup),
            ]
        })
        .collect();
    let header = ["r".into(), "steal cycles".into(), "rsp ×".into(), "srsp ×".into()];
    println!(
        "Remote-ratio crossover — STRESS — speedup vs global-scope stealing\n{}",
        format_table(&header, &rows)
    );
}
