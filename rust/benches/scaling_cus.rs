//! Bench: the scalability claim (§1/§7) — geomean speedup of RSP vs sRSP
//! as CU count grows. Naive RSP's all-L1 promotions erase its advantage
//! at scale; sRSP holds steady (that is the paper's thesis).

mod bench_common;
use srsp::harness::figures::scaling_sweep_jobs;
use srsp::harness::report::format_table;

fn main() {
    let (_, size) = bench_common::parse_args();
    let cus = [4u32, 8, 16, 32, 64];
    // jobs=1: wall time measures simulator cost, not host parallelism.
    let rows = bench_common::timed("scaling sweep", || scaling_sweep_jobs(&cus, size, 1));
    let header = vec!["CUs".into(), "RSP".into(), "sRSP".into()];
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|(n, r, s)| vec![n.to_string(), format!("{r:.3}"), format!("{s:.3}")])
        .collect();
    println!(
        "Scalability — geomean speedup vs Baseline at equal CU count\n{}",
        format_table(&header, &body)
    );
    let (first, last) = (rows.first().unwrap(), rows.last().unwrap());
    assert!(
        last.1 < first.1,
        "naive RSP must degrade with CU count ({} -> {})",
        first.1,
        last.1
    );
    assert!(
        last.2 > last.1,
        "sRSP must beat naive RSP at full scale"
    );
}
