//! Bench: ablations over the sRSP hardware parameters called out in
//! DESIGN.md — LR-TBL / PA-TBL capacity and sFIFO depth — on the SSSP
//! road-network workload (the steal-heaviest input).
//!
//! Expected shape: tiny tables force conservative full drains / eager
//! invalidates and cost performance; the Table-1 sizes (16/16/16) sit on
//! the knee; larger sizes buy little.

mod bench_common;
use srsp::config::{DeviceConfig, Scenario};
use srsp::harness::figures::run_one;
use srsp::harness::presets::{WorkloadPreset, WorkloadSize};
use srsp::harness::report::format_table;
use srsp::workload::registry;

fn run_with(cfg: &DeviceConfig, size: WorkloadSize) -> u64 {
    let preset = WorkloadPreset::new(registry::SSSP, size);
    run_one(cfg, &preset, Scenario::SRSP).stats.cycles
}

fn main() {
    let (base_cfg, size) = bench_common::parse_args();

    let mut rows = Vec::new();
    for lr in [0u32, 4, 16, 64] {
        for pa in [4u32, 16, 64] {
            let cfg = DeviceConfig {
                lr_tbl_entries: lr,
                pa_tbl_entries: pa,
                ..base_cfg.clone()
            };
            let cycles = bench_common::timed(&format!("lr={lr} pa={pa}"), || {
                run_with(&cfg, size)
            });
            rows.push(vec![lr.to_string(), pa.to_string(), cycles.to_string()]);
        }
    }
    println!(
        "Ablation — SSSP/sRSP cycles vs table capacities\n{}",
        format_table(
            &["LR-TBL".into(), "PA-TBL".into(), "cycles".into()],
            &rows
        )
    );

    let mut rows = Vec::new();
    for sfifo in [4u32, 8, 16, 32, 64] {
        let cfg = DeviceConfig {
            l1_sfifo: sfifo,
            ..base_cfg.clone()
        };
        let cycles = bench_common::timed(&format!("sfifo={sfifo}"), || run_with(&cfg, size));
        rows.push(vec![sfifo.to_string(), cycles.to_string()]);
    }
    println!(
        "Ablation — SSSP/sRSP cycles vs sFIFO depth\n{}",
        format_table(&["sFIFO".into(), "cycles".into()], &rows)
    );
}
