//! Memory-consistency litmus tests over the simulated hierarchy.
//!
//! Value-accurate caches make these meaningful: stale reads really happen
//! when the model allows them, and must never happen across a proper
//! acquire/release edge.

use srsp::config::{DeviceConfig, Protocol};
use srsp::gpu::Device;
use srsp::kir::{Asm, Program, Src};
use srsp::sync::{AtomicOp, MemOrder, Scope};

const DATA: u64 = 0x1000;
const FLAG: u64 = 0x1040;
const OUT: u64 = 0x2000;

fn all_protocols() -> [Protocol; 3] {
    [Protocol::SCOPED_ONLY, Protocol::RSP_NAIVE, Protocol::SRSP]
}

/// Message passing at cmp scope: the acquiring reader must see the data
/// written before the release, on every protocol.
fn mp_kernel(scope: Scope) -> Program {
    let mut a = Asm::new();
    let wg = a.reg();
    let data = a.reg();
    let flag = a.reg();
    let v = a.reg();
    let out = a.reg();
    a.wg_id(wg);
    a.imm(data, DATA);
    a.imm(flag, FLAG);
    a.bnz(wg, "reader");
    // writer
    a.imm(v, 42);
    a.st(data, 0, v, 4);
    a.atomic(v, AtomicOp::Store, flag, Src::I(1), Src::I(0), MemOrder::Release, scope);
    a.halt();
    // reader: spin on flag with acquire, then read data.
    a.label("reader");
    a.label("spin");
    a.atomic(v, AtomicOp::Load, flag, Src::I(0), Src::I(0), MemOrder::Acquire, scope);
    a.bz(v, "spin");
    a.ld(v, data, 0, 4);
    a.imm(out, OUT);
    a.st(out, 0, v, 4);
    a.halt();
    a.finish()
}

#[test]
fn message_passing_cmp_scope_all_protocols() {
    for p in all_protocols() {
        let mut dev = Device::new(DeviceConfig::small(), p);
        dev.launch_simple(&mp_kernel(Scope::Cmp), 2);
        assert_eq!(
            dev.mem.backing.read_u32(OUT),
            42,
            "{p:?}: acquire must observe pre-release data"
        );
    }
}

#[test]
fn message_passing_wg_scope_same_cu() {
    // Two work-groups on the SAME CU share an L1: wg scope suffices.
    let cfg = DeviceConfig {
        num_cus: 1,
        wgs_per_cu: 2,
        ..DeviceConfig::small()
    };
    for p in all_protocols() {
        let mut dev = Device::new(cfg.clone(), p);
        dev.launch_simple(&mp_kernel(Scope::Wg), 2);
        assert_eq!(
            dev.mem.backing.read_u32(OUT),
            42,
            "{p:?}: wg scope within one CU must synchronize"
        );
    }
}

/// Demonstrate permitted staleness: a plain cross-CU read with *no*
/// synchronization may legitimately miss the writer's dirty data; after a
/// cmp acquire/release pair it must be visible.
#[test]
fn unsynchronized_cross_cu_read_is_stale() {
    let mut dev = Device::new(DeviceConfig::small(), Protocol::SRSP);
    // CU0 writes (stays dirty in its L1).
    let t = dev.mem.l1_write(0, DATA, 4, 7, 0);
    // CU1 plain read: L2 has no idea -> 0.
    let (v, t2) = dev.mem.l1_read(1, DATA, 4, t);
    assert_eq!(v, 0, "non-coherent L1s must yield the stale value");
    // Proper pair: CU0 releases at cmp scope, CU1 acquires.
    let rel = srsp::sync::engine::sync_op(
        &mut dev.mem, Protocol::SRSP, 0, FLAG, AtomicOp::Store,
        MemOrder::Release, Scope::Cmp, 1, 0, t2,
    );
    let acq = srsp::sync::engine::sync_op(
        &mut dev.mem, Protocol::SRSP, 1, FLAG, AtomicOp::Load,
        MemOrder::Acquire, Scope::Cmp, 0, 0, rel.done,
    );
    assert_eq!(acq.value, 1);
    let (v2, _) = dev.mem.l1_read(1, DATA, 4, acq.done);
    assert_eq!(v2, 7, "cmp acquire/release must publish the data");
}

/// Remote lock handoff (the paper's §4 example) as a full KIR program:
/// local sharer takes the lock n0 times, remote sharer n1 times; the
/// protected counter must be exact under both RSP implementations.
fn handoff_kernel(n0: u64, n1: u64, remote: bool) -> Program {
    let mut a = Asm::new();
    let wg = a.reg();
    let lock = a.reg();
    let data = a.reg();
    let old = a.reg();
    let tmp = a.reg();
    let i = a.reg();
    let c = a.reg();
    a.wg_id(wg);
    a.imm(lock, FLAG);
    a.imm(data, DATA);
    a.imm(i, 0);
    a.bnz(wg, "remote_side");

    a.label("l_loop");
    a.lt_u(c, i, Src::I(n0));
    a.bz(c, "l_done");
    a.label("l_spin");
    a.atomic(old, AtomicOp::Cas, lock, Src::I(1), Src::I(0), MemOrder::Acquire, Scope::Wg);
    a.bnz(old, "l_spin");
    a.ld(tmp, data, 0, 4);
    a.add(tmp, tmp, Src::I(1));
    a.st(data, 0, tmp, 4);
    a.atomic(old, AtomicOp::Store, lock, Src::I(0), Src::I(0), MemOrder::Release, Scope::Wg);
    a.add(i, i, Src::I(1));
    a.br("l_loop");
    a.label("l_done");
    a.halt();

    a.label("remote_side");
    a.label("r_loop");
    a.lt_u(c, i, Src::I(n1));
    a.bz(c, "r_done");
    a.label("r_spin");
    if remote {
        a.remote_atomic(old, AtomicOp::Cas, lock, Src::I(1), Src::I(0), MemOrder::Acquire);
    } else {
        a.atomic(old, AtomicOp::Cas, lock, Src::I(1), Src::I(0), MemOrder::Acquire, Scope::Cmp);
    }
    a.bnz(old, "r_spin");
    a.ld(tmp, data, 0, 4);
    a.add(tmp, tmp, Src::I(1));
    a.st(data, 0, tmp, 4);
    if remote {
        a.remote_atomic(old, AtomicOp::Store, lock, Src::I(0), Src::I(0), MemOrder::Release);
    } else {
        a.atomic(old, AtomicOp::Store, lock, Src::I(0), Src::I(0), MemOrder::Release, Scope::Cmp);
    }
    a.add(i, i, Src::I(1));
    a.br("r_loop");
    a.label("r_done");
    a.halt();
    a.finish()
}

#[test]
fn remote_lock_handoff_exact_rsp_and_srsp() {
    for p in [Protocol::RSP_NAIVE, Protocol::SRSP] {
        for (n0, n1) in [(1u64, 1u64), (3, 1), (17, 5), (50, 13)] {
            let mut dev = Device::new(DeviceConfig::small(), p);
            dev.launch_simple(&handoff_kernel(n0, n1, true), 2);
            assert_eq!(
                dev.mem.backing.read_u32(DATA) as u64,
                n0 + n1,
                "{p:?} ({n0},{n1}): mutual exclusion must hold"
            );
        }
    }
}

#[test]
fn lock_handoff_many_remote_sharers() {
    // One local sharer + 3 remote sharers hammering the same lock.
    let mut a = Asm::new();
    let wg = a.reg();
    let lock = a.reg();
    let data = a.reg();
    let old = a.reg();
    let tmp = a.reg();
    let i = a.reg();
    let c = a.reg();
    a.wg_id(wg);
    a.imm(lock, FLAG);
    a.imm(data, DATA);
    a.imm(i, 0);
    a.bnz(wg, "thief");
    a.label("o_loop");
    a.lt_u(c, i, Src::I(30));
    a.bz(c, "done");
    a.label("o_spin");
    a.atomic(old, AtomicOp::Cas, lock, Src::I(1), Src::I(0), MemOrder::Acquire, Scope::Wg);
    a.bnz(old, "o_spin");
    a.ld(tmp, data, 0, 4);
    a.add(tmp, tmp, Src::I(1));
    a.st(data, 0, tmp, 4);
    a.atomic(old, AtomicOp::Store, lock, Src::I(0), Src::I(0), MemOrder::Release, Scope::Wg);
    a.add(i, i, Src::I(1));
    a.br("o_loop");
    a.label("thief");
    a.label("t_loop");
    a.lt_u(c, i, Src::I(5));
    a.bz(c, "done");
    a.label("t_spin");
    a.remote_atomic(old, AtomicOp::Cas, lock, Src::I(1), Src::I(0), MemOrder::Acquire);
    a.bnz(old, "t_spin");
    a.ld(tmp, data, 0, 4);
    a.add(tmp, tmp, Src::I(1));
    a.st(data, 0, tmp, 4);
    a.remote_atomic(old, AtomicOp::Store, lock, Src::I(0), Src::I(0), MemOrder::Release);
    a.add(i, i, Src::I(1));
    a.br("t_loop");
    a.label("done");
    a.halt();
    let p = a.finish();

    for proto in [Protocol::RSP_NAIVE, Protocol::SRSP] {
        let mut dev = Device::new(DeviceConfig::small(), proto);
        dev.launch_simple(&p, 4);
        assert_eq!(
            dev.mem.backing.read_u32(DATA),
            30 + 3 * 5,
            "{proto:?}: counter must be exact with multiple remote sharers"
        );
    }
}

/// rem_ar as a full fence: a remote fetch-add both observes the local
/// sharer's preceding writes and publishes its own.
#[test]
fn rem_ar_fetch_add_counter_exact() {
    let mut a = Asm::new();
    let wg = a.reg();
    let ctr = a.reg();
    let old = a.reg();
    let i = a.reg();
    let c = a.reg();
    a.wg_id(wg);
    a.imm(ctr, FLAG);
    a.imm(i, 0);
    a.bnz(wg, "rem");
    a.label("loc_loop");
    a.atomic(old, AtomicOp::Add, ctr, Src::I(1), Src::I(0), MemOrder::AcqRel, Scope::Wg);
    a.add(i, i, Src::I(1));
    a.lt_u(c, i, Src::I(40));
    a.bnz(c, "loc_loop");
    a.halt();
    a.label("rem");
    a.label("rem_loop");
    a.remote_atomic(old, AtomicOp::Add, ctr, Src::I(1), Src::I(0), MemOrder::AcqRel);
    a.add(i, i, Src::I(1));
    a.lt_u(c, i, Src::I(6));
    a.bnz(c, "rem_loop");
    a.halt();
    let p = a.finish();

    for proto in [Protocol::RSP_NAIVE, Protocol::SRSP] {
        let mut dev = Device::new(DeviceConfig::small(), proto);
        dev.launch_simple(&p, 3);
        assert_eq!(
            dev.mem.backing.read_u32(FLAG),
            40 + 2 * 6,
            "{proto:?}: mixed-scope fetch-adds must not lose increments"
        );
    }
}
