//! The remote-ratio sweep axis (protocol × r on the stress family):
//! every protocol must pass the stress oracle at every sample point, the
//! report must carry the axis as a first-class column, and the sweep
//! must actually measure what it claims — sRSP's selective promotion
//! doing less invalidation work than naive RSP's flush-all at the
//! remote-heavy end. Since PR 4 the sweep is a one-axis
//! [`SweepPlan`] through the generic `run_sweep`; the axis itself lives
//! in the `coordinator::axis` registry.

use std::process::Command;

use srsp::config::{DeviceConfig, Scenario};
use srsp::coordinator::{axis, Seeding, SweepPlan, RATIO_SCENARIOS};
use srsp::harness::presets::WorkloadSize;
use srsp::harness::report::Report;
use srsp::harness::runner::Runner;
use srsp::workload::registry;

fn tiny_runner() -> Runner {
    Runner {
        validate: true,
        seeding: Seeding::PerCell(7),
        ..Runner::new(DeviceConfig::small(), WorkloadSize::Tiny, 4)
    }
}

fn ratio_plan(points: &[f64]) -> SweepPlan {
    SweepPlan::new(registry::STRESS, &[axis::REMOTE_RATIO])
        .unwrap()
        .with_points(axis::REMOTE_RATIO, points.to_vec())
        .unwrap()
}

#[test]
fn all_protocols_pass_oracles_at_every_ratio() {
    let points = [0.0, 0.1, 0.5, 1.0];
    let results = tiny_runner().run_sweep(&ratio_plan(&points));
    assert_eq!(results.len(), points.len() * RATIO_SCENARIOS.len());
    for (i, c) in results.iter().enumerate() {
        // Combo-major order: all protocols of one r adjacent.
        let (r, scenario) = (points[i / 3], RATIO_SCENARIOS[i % 3]);
        assert_eq!(c.cell.scenario, scenario);
        assert_eq!(c.remote_ratio, Some(r));
        assert_eq!(c.axis_values, format!("remote-ratio={r}"));
        assert_eq!(
            c.validated,
            Some(true),
            "{scenario:?} failed the stress oracle at r={r}"
        );
    }
    let csv = Report::from_cells(&results).to_csv();
    assert_eq!(csv.lines().count(), results.len() + 1);
    assert!(csv.contains("remote_ratio"));
    assert!(csv.contains("axis_values"));
}

#[test]
fn srsp_invalidates_less_than_naive_at_the_skewed_end() {
    let results = tiny_runner().run_sweep(&ratio_plan(&[1.0]));
    let cell = |scenario: Scenario| {
        results
            .iter()
            .find(|c| c.cell.scenario == scenario)
            .unwrap()
            .clone()
    };
    let rsp = cell(Scenario::RSP).result.stats;
    let srsp = cell(Scenario::SRSP).result.stats;
    assert!(
        rsp.l1_invalidates > srsp.l1_invalidates,
        "naive RSP must flush+invalidate more L1s than selective sRSP \
         ({} vs {})",
        rsp.l1_invalidates,
        srsp.l1_invalidates
    );
    assert!(
        srsp.selective_flush_nops > 0,
        "sRSP must answer LR-TBL misses with nop acks"
    );
}

#[test]
fn cli_remote_ratio_sweep_round_trips() {
    let out = Command::new(env!("CARGO_BIN_EXE_srsp"))
        .args(["sweep", "--axis", "remote-ratio", "--size", "tiny", "--cus", "4"])
        .args(["--ratios", "0,0.1", "--jobs", "2", "--report", "csv"])
        .output()
        .expect("spawn srsp");
    assert!(
        out.status.success(),
        "{}",
        String::from_utf8_lossy(&out.stderr)
    );
    let csv = String::from_utf8_lossy(&out.stdout);
    let lines: Vec<&str> = csv.lines().collect();
    assert_eq!(lines.len(), 1 + 2 * 3, "header + 2 ratios × 3 protocols");
    assert!(lines[0].contains("remote_ratio"));
    for line in &lines[1..] {
        assert!(line.contains("STRESS"), "{line}");
        assert!(line.contains(",true,"), "oracle-validated row: {line}");
    }
}
