//! The sync-event tracing subsystem end to end. The acceptance
//! properties: tracing is **observe-only** — a traced run's report is
//! byte-identical to the untraced run's — and the trace file itself is
//! byte-identical for any `--jobs` / `--workers` split of the same
//! grid. Ring overflow is loud (`"truncated":true`), the trace flags
//! are scoped to the commands that consume them, and the `srsp trace`
//! surface renders every kind from a recorded file.

use std::path::PathBuf;
use std::process::Command;

use srsp::config::DeviceConfig;
use srsp::coordinator::{axis, SweepPlan};
use srsp::harness::presets::WorkloadSize;
use srsp::harness::report::Report;
use srsp::harness::runner::Runner;
use srsp::harness::tracefile::TraceReport;
use srsp::workload::registry;

fn srsp_bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_srsp"))
}

/// A scratch directory unique to this test process + test name.
fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("srsp-trace-test-{}-{name}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("create scratch dir");
    dir
}

fn tiny_runner(trace_capacity: u32, jobs: usize) -> Runner {
    Runner {
        validate: true,
        ..Runner::new(
            DeviceConfig {
                num_cus: 4,
                trace_capacity,
                ..DeviceConfig::small()
            },
            WorkloadSize::Tiny,
            jobs,
        )
    }
}

fn ratio_plan() -> SweepPlan {
    SweepPlan::new(registry::STRESS, &[axis::REMOTE_RATIO])
        .unwrap()
        .with_points(axis::REMOTE_RATIO, vec![0.0, 0.5])
        .unwrap()
}

/// The shared sweep invocation for the CLI matrix tests.
fn sweep_args(cmd: &mut Command) -> &mut Command {
    cmd.args(["sweep", "--axis", "remote-ratio", "--app", "stress"])
        .args(["--size", "tiny", "--cus", "4"])
        .args(["--ratios", "0,0.5"])
}

/// Library level: tracing never perturbs simulation, and the harvested
/// trace is identical for any in-process jobs split.
#[test]
fn tracing_is_observe_only_and_jobs_invariant() {
    let plan = ratio_plan();
    let untraced = Report::from_cells(&tiny_runner(0, 1).run_sweep(&plan));
    let traced_cells = tiny_runner(4096, 1).run_sweep(&plan);
    let traced = Report::from_cells(&traced_cells);
    assert_eq!(
        untraced.to_json(),
        traced.to_json(),
        "tracing must not change any reported result"
    );
    assert_eq!(untraced.to_csv(), traced.to_csv());

    let jsonl1 = TraceReport::from_cells(&traced_cells).unwrap().render_jsonl();
    let jsonl4 = TraceReport::from_cells(&tiny_runner(4096, 4).run_sweep(&plan))
        .unwrap()
        .render_jsonl();
    assert_eq!(jsonl1, jsonl4, "--jobs must not change the trace");
    assert!(jsonl1.contains("\"kind\":\"promotion\""), "srsp cells must promote");
    assert!(jsonl1.contains("\"truncated\":false"));

    // The JSONL file round-trips losslessly.
    let parsed = TraceReport::parse_jsonl(&jsonl1).unwrap();
    assert_eq!(parsed.render_jsonl(), jsonl1);
}

/// CLI level, the acceptance gate: the trace file from `--workers 2` is
/// byte-identical to `--jobs 4` and `--jobs 1`, and the traced report is
/// byte-identical to the untraced one.
#[test]
fn cli_trace_byte_identical_across_jobs_and_workers() {
    let dir = scratch("jobs-vs-workers");
    let run = |mode: &[&str], trace: Option<&PathBuf>, report: &PathBuf| {
        let mut cmd = srsp_bin();
        sweep_args(&mut cmd)
            .args(mode)
            .args(["--report", "json", "--out", report.to_str().unwrap()]);
        if let Some(t) = trace {
            cmd.args(["--trace", t.to_str().unwrap()]);
        }
        let out = cmd.output().expect("spawn srsp");
        assert!(
            out.status.success(),
            "sweep {mode:?}: {}",
            String::from_utf8_lossy(&out.stderr)
        );
    };
    let (t1, t4, tw) = (dir.join("t1.jsonl"), dir.join("t4.jsonl"), dir.join("tw.jsonl"));
    let (r1, r4, rw, r0) = (
        dir.join("r1.json"),
        dir.join("r4.json"),
        dir.join("rw.json"),
        dir.join("r0.json"),
    );
    run(&["--jobs", "1"], Some(&t1), &r1);
    run(&["--jobs", "4"], Some(&t4), &r4);
    run(&["--workers", "2"], Some(&tw), &rw);
    run(&["--jobs", "4"], None, &r0); // untraced control

    let (t1, t4, tw) = (
        std::fs::read(&t1).unwrap(),
        std::fs::read(&t4).unwrap(),
        std::fs::read(&tw).unwrap(),
    );
    assert!(!t1.is_empty());
    assert_eq!(t1, t4, "--jobs 4 trace must be byte-identical to --jobs 1");
    assert_eq!(t1, tw, "--workers 2 trace must be byte-identical to --jobs 1");
    let text = String::from_utf8(t1).unwrap();
    assert!(text.starts_with("{\"schema\":"), "schema header first:\n{text}");
    assert!(text.contains("\"kind\":\"promotion\""));

    let (r1, r4, rw, r0) = (
        std::fs::read(&r1).unwrap(),
        std::fs::read(&r4).unwrap(),
        std::fs::read(&rw).unwrap(),
        std::fs::read(&r0).unwrap(),
    );
    assert_eq!(r1, r0, "tracing must not change the report (observe-only)");
    assert_eq!(r1, r4);
    assert_eq!(r1, rw);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Ring overflow is loud: a tiny `--trace-buf` marks the cell truncated
/// in both the JSONL file and the `trace summary` rendering, and still
/// leaves the report untouched.
#[test]
fn trace_ring_overflow_is_loud() {
    let dir = scratch("overflow");
    let trace = dir.join("small.jsonl");
    let out = srsp_bin()
        .args(["run", "--app", "stress", "--scenario", "srsp", "--size", "tiny"])
        .args(["--cus", "4", "--param", "remote_ratio=0.5"])
        .args(["--trace", trace.to_str().unwrap(), "--trace-buf", "16"])
        .output()
        .expect("spawn srsp");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = std::fs::read_to_string(&trace).unwrap();
    assert!(text.contains("\"truncated\":true"), "16-event ring must overflow:\n{text}");
    let report = TraceReport::parse_jsonl(&text).unwrap();
    assert_eq!(report.cells.len(), 1);
    assert!(report.cells[0].trace.truncated());
    assert_eq!(report.cells[0].trace.events.len(), 16, "ring keeps the newest 16");
    // Per-CU counters are not ring-bound: they keep counting past the drop.
    let counted: u64 = report.cells[0].trace.cu_totals().iter().sum();
    assert!(counted > 16, "per-CU counts must survive overflow (got {counted})");
    let summary = srsp_bin()
        .args(["trace", "summary", "--trace", trace.to_str().unwrap()])
        .output()
        .expect("spawn srsp trace");
    assert!(summary.status.success());
    let summary = String::from_utf8_lossy(&summary.stdout).to_string();
    assert!(summary.contains("TRUNCATED"), "summary must shout:\n{summary}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// The `srsp trace` render surface over a real recorded file.
#[test]
fn cli_trace_renders_summary_timeline_perfetto_kinds() {
    let dir = scratch("render");
    let trace = dir.join("t.jsonl");
    let mut cmd = srsp_bin();
    let out = sweep_args(&mut cmd)
        .args(["--jobs", "2", "--trace", trace.to_str().unwrap()])
        .output()
        .expect("spawn srsp");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    let render = |kind: &str| {
        let out = srsp_bin()
            .args(["trace", kind, "--trace", trace.to_str().unwrap()])
            .output()
            .expect("spawn srsp trace");
        assert!(
            out.status.success(),
            "trace {kind}: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        String::from_utf8_lossy(&out.stdout).to_string()
    };
    let summary = render("summary");
    assert!(summary.contains("cell 0: stress/"), "{summary}");
    assert!(summary.contains("promo"), "{summary}");
    let timeline = render("timeline");
    assert!(timeline.contains("bucket_start"), "{timeline}");
    let perfetto = render("perfetto");
    assert!(perfetto.starts_with("{\"traceEvents\":["), "{perfetto}");
    assert!(perfetto.contains("\"thread_name\""), "{perfetto}");
    let kinds = render("kinds");
    assert!(kinds.contains("sel_flush_nop"), "{kinds}");
    // Default kind is summary; --out writes instead of printing.
    let out_path = dir.join("summary.txt");
    let out = srsp_bin()
        .args(["trace", "--trace", trace.to_str().unwrap()])
        .args(["--out", out_path.to_str().unwrap()])
        .output()
        .expect("spawn srsp trace");
    assert!(out.status.success());
    assert_eq!(std::fs::read_to_string(&out_path).unwrap(), summary);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The trace flags are scoped: commands that would silently ignore them
/// reject them up front, and `trace` itself names a missing input.
#[test]
fn cli_rejects_misplaced_trace_flags() {
    for (args, needle) in [
        (vec!["ci-smoke", "--trace", "t.jsonl"], "--trace applies to"),
        (vec!["validate", "--trace", "t.jsonl"], "--trace applies to"),
        (vec!["fig4", "--trace", "t.jsonl"], "--trace applies to"),
        (vec!["bench", "--trace", "t.jsonl"], "--trace applies to"),
        (vec!["list-axes", "--trace", "t.jsonl"], "--trace applies to"),
        (vec!["merge-reports", "--trace", "t.jsonl"], "--trace applies to"),
        (vec!["run", "--trace-buf", "64"], "needs --trace"),
        (vec!["worker", "--trace", "t.jsonl", "--trace-buf", "64"], "--trace-buf applies to"),
        (vec!["run", "--trace", "t.jsonl", "--trace-buf", "0"], "at least 1"),
        (vec!["worker", "--shard", "s.json", "--trace", "t.jsonl"], "--out"),
        (vec!["trace"], "needs --trace"),
        (vec!["trace", "nonsense", "--trace", "t.jsonl"], "unknown trace kind"),
    ] {
        let out = srsp_bin().args(&args).output().expect("spawn srsp");
        assert!(!out.status.success(), "{args:?} must be rejected");
        let stderr = String::from_utf8_lossy(&out.stderr);
        assert!(stderr.contains(needle), "{args:?}: expected '{needle}' in:\n{stderr}");
    }
}
