//! Protocol-registry round-trip: every registered sync protocol is
//! selectable **by name** from the CLI — the registry is the single
//! source of truth for protocol dispatch, and no protocol enum exists
//! outside it. Mirrors `registry_roundtrip.rs` (the workload registry's
//! round-trip) at the sync layer, plus the refactor's equivalence
//! property: the classic figure grid must produce **byte-identical**
//! reports whether its scenarios come from the legacy constants or are
//! re-resolved through registry names.

use std::process::Command;

use srsp::config::{DeviceConfig, Scenario};
use srsp::coordinator::{classic_grid, Cell, Seeding};
use srsp::harness::presets::WorkloadSize;
use srsp::harness::report::Report;
use srsp::harness::runner::Runner;
use srsp::sync::protocol;

fn srsp_bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_srsp"))
}

#[test]
fn registry_holds_five_protocols() {
    assert_eq!(protocol::all().count(), 5);
    for name in ["scoped", "rsp", "srsp", "hlrc", "srsp-adaptive"] {
        assert!(protocol::resolve(name).is_some(), "{name} must resolve");
    }
}

#[test]
fn list_protocols_covers_the_registry() {
    let out = srsp_bin().arg("list-protocols").output().expect("spawn srsp");
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for id in protocol::all() {
        assert!(
            text.contains(id.name()),
            "'{}' missing from list-protocols:\n{text}",
            id.name()
        );
    }
}

#[test]
fn scenarios_round_trip_through_registry_names() {
    let mut scenarios: Vec<Scenario> = Scenario::ALL.to_vec();
    scenarios.extend(protocol::all().map(Scenario::for_protocol));
    for s in scenarios {
        assert_eq!(Scenario::from_name(s.name()), Some(s), "{}", s.name());
    }
}

/// The refactor's acceptance property: dispatching the classic grid via
/// registry names (name → protocol → scenario) must reproduce the
/// legacy-constant grid bit-for-bit, reports included.
#[test]
fn classic_grid_reports_identical_via_registry_names() {
    let legacy = classic_grid(4);
    let by_name: Vec<Cell> = legacy
        .iter()
        .map(|c| Cell {
            scenario: Scenario::from_name(c.scenario.name())
                .unwrap_or_else(|| panic!("scenario '{}' must resolve", c.scenario.name())),
            ..*c
        })
        .collect();
    let runner = Runner {
        seeding: Seeding::PerCell(42),
        validate: true,
        ..Runner::new(
            DeviceConfig {
                num_cus: 4,
                ..DeviceConfig::small()
            },
            WorkloadSize::Tiny,
            4,
        )
    };
    let a = runner.run_cells(&legacy);
    let b = runner.run_cells(&by_name);
    assert_eq!(
        format!("{a:?}"),
        format!("{b:?}"),
        "registry-name dispatch must not change any cell result"
    );
    for c in &a {
        assert_eq!(c.validated, Some(true), "{}/{}", c.result.app, c.result.scenario);
    }
    let ra = Report::from_cells(&a);
    let rb = Report::from_cells(&b);
    assert_eq!(ra.to_csv(), rb.to_csv(), "CSV reports must be byte-identical");
    assert_eq!(ra.to_json(), rb.to_json(), "JSON reports must be byte-identical");
}

#[test]
fn srsp_adaptive_and_lock_selectable_purely_by_name() {
    // The new protocol and the new workload are reachable from the CLI
    // by registry name alone — no enum was extended to land them.
    let out = srsp_bin()
        .args(["run", "--app", "lock", "--protocol", "srsp-adaptive"])
        .args(["--size", "tiny", "--cus", "4"])
        .output()
        .expect("spawn srsp");
    assert!(
        out.status.success(),
        "{}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("scenario=srsp-adaptive"), "{text}");
    assert!(text.contains("converged=true"), "{text}");

    // `--scenario` resolves protocol names through the same registry.
    let out = srsp_bin()
        .args(["run", "--app", "stress", "--scenario", "srsp-adaptive"])
        .args(["--size", "tiny", "--cus", "4"])
        .output()
        .expect("spawn srsp");
    assert!(
        out.status.success(),
        "{}",
        String::from_utf8_lossy(&out.stderr)
    );
}

#[test]
fn proto_params_reach_the_device_and_unknown_keys_fail() {
    let out = srsp_bin()
        .args(["run", "--app", "stress", "--protocol", "srsp"])
        .args(["--size", "tiny", "--cus", "4"])
        .args(["--proto-param", "lr_tbl_entries=1", "--proto-param", "pa_tbl_entries=1"])
        .output()
        .expect("spawn srsp");
    assert!(
        out.status.success(),
        "{}",
        String::from_utf8_lossy(&out.stderr)
    );

    let out = srsp_bin()
        .args(["run", "--app", "stress", "--protocol", "srsp"])
        .args(["--proto-param", "bogus=1"])
        .output()
        .expect("spawn srsp");
    assert!(!out.status.success());
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("unknown parameter"),
        "the error must name the bad key"
    );
}

#[test]
fn protocol_flag_rejected_where_it_would_be_ignored() {
    // Matrix commands run fixed scenario grids; silently ignoring
    // `--protocol` would let the user believe the grid ran their
    // protocol. The CLI must refuse, like it does for bad --param keys.
    for cmd in [
        &["validate", "--protocol", "srsp-adaptive"][..],
        &["ci-smoke", "--protocol", "hlrc"][..],
        &["sweep", "--axis", "cu-count", "--protocol", "hlrc"][..],
    ] {
        let out = srsp_bin().args(cmd).output().expect("spawn srsp");
        assert!(!out.status.success(), "{cmd:?} must refuse --protocol");
        assert!(
            String::from_utf8_lossy(&out.stderr).contains("--protocol"),
            "{cmd:?}: error must name the flag"
        );
    }
}

#[test]
fn axis_flags_rejected_on_the_wrong_axis() {
    // `--cus` vs `--cu-counts` invites a mix-up the CLI must catch: on
    // the cu-count axis the device size comes from the grid points and
    // `--cus` would be silently ignored.
    for cmd in [
        &["sweep", "--axis", "cu-count", "--cus", "8"][..],
        &["sweep", "--axis", "cu-count", "--ratios", "0,0.5"][..],
        &["sweep", "--axis", "remote-ratio", "--cu-counts", "4,8"][..],
        &["run", "--app", "stress", "--cu-counts", "4,8"][..],
    ] {
        let out = srsp_bin().args(cmd).output().expect("spawn srsp");
        assert!(!out.status.success(), "{cmd:?} must be rejected");
    }
}

#[test]
fn negative_proto_param_values_are_rejected() {
    // `lr_tbl_entries=-1` would silently saturate to 0 (sticky-overflow
    // mode) while the report claimed -1 was honored.
    let out = srsp_bin()
        .args(["run", "--app", "stress", "--protocol", "srsp"])
        .args(["--proto-param", "lr_tbl_entries=-1"])
        .output()
        .expect("spawn srsp");
    assert!(!out.status.success());
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("non-negative"),
        "the error must explain the range"
    );
}

#[test]
fn unknown_protocol_name_lists_the_registered_ones() {
    let out = srsp_bin()
        .args(["run", "--protocol", "bogus"])
        .output()
        .expect("spawn srsp");
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    for id in protocol::all() {
        assert!(err.contains(id.name()), "error must list '{}':\n{err}", id.name());
    }
}

#[test]
fn cli_cu_count_sweep_round_trips() {
    let out = srsp_bin()
        .args(["sweep", "--axis", "cu-count", "--size", "tiny"])
        .args(["--cu-counts", "2,4", "--jobs", "2", "--report", "csv"])
        .output()
        .expect("spawn srsp");
    assert!(
        out.status.success(),
        "{}",
        String::from_utf8_lossy(&out.stderr)
    );
    let csv = String::from_utf8_lossy(&out.stdout);
    let lines: Vec<&str> = csv.lines().collect();
    assert_eq!(lines.len(), 1 + 2 * 3, "header + 2 CU counts × 3 protocols");
    assert!(lines[0].starts_with("app,scenario,cus,"));
    for line in &lines[1..] {
        assert!(line.contains("STRESS"), "{line}");
        assert!(line.contains(",true,"), "oracle-validated row: {line}");
    }
}
