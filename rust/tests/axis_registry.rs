//! Sweep-axis-registry round-trip: every registered axis is selectable
//! **by name** from the CLI, axes compose into cross-product surfaces,
//! and the generic `run_sweep` reproduces the deleted single-axis sweep
//! paths **exactly**. Mirrors `registry_roundtrip.rs` (workloads) and
//! `protocol_registry.rs` (protocols) at the sweep layer — the third
//! registry of the trilogy.

use std::process::Command;

use srsp::config::DeviceConfig;
use srsp::coordinator::{axis, Cell, Seeding, SweepPlan, RATIO_SCENARIOS};
use srsp::harness::presets::WorkloadSize;
use srsp::harness::report::{Report, REPORT_SCHEMA};
use srsp::harness::runner::Runner;
use srsp::workload::registry;

fn srsp_bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_srsp"))
}

fn tiny_runner() -> Runner {
    Runner {
        validate: true,
        seeding: Seeding::PerCell(5),
        ..Runner::new(
            DeviceConfig {
                num_cus: 4,
                ..DeviceConfig::small()
            },
            WorkloadSize::Tiny,
            4,
        )
    }
}

#[test]
fn registry_holds_five_axes() {
    assert_eq!(axis::all().count(), 5);
    for name in ["remote-ratio", "cu-count", "hot-set", "migration", "lr-tbl-entries"] {
        assert!(axis::resolve(name).is_some(), "{name} must resolve");
    }
}

#[test]
fn list_axes_covers_the_registry() {
    let out = srsp_bin().arg("list-axes").output().expect("spawn srsp");
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for id in axis::all() {
        assert!(
            text.contains(id.name()),
            "'{}' missing from list-axes:\n{text}",
            id.name()
        );
    }
    // The default points and the driven parameter are self-described.
    assert!(text.contains("--param remote_ratio"), "{text}");
    assert!(text.contains("device num_cus"), "{text}");
}

/// The refactor's acceptance property, remote-ratio side: the generic
/// `run_sweep` must reproduce what the deleted `run_remote_ratio_sweep`
/// computed — per point, the exact cells a plain `run_cells` with the
/// point's parameter override produces, reports included.
#[test]
fn single_axis_remote_ratio_equivalent_to_legacy_per_point_grids() {
    let points = [0.0, 0.5];
    let runner = tiny_runner();
    let plan = SweepPlan::new(registry::STRESS, &[axis::REMOTE_RATIO])
        .unwrap()
        .with_points(axis::REMOTE_RATIO, points.to_vec())
        .unwrap();
    let generic = runner.run_sweep(&plan);

    // Legacy semantics, reconstructed independently: ratio-major cell
    // order, one shared input per point (seeds ignore both the scenario
    // and the ratio), the ratio applied as a workload-param override.
    let mut legacy = Vec::new();
    for &r in &points {
        let mut per_point = runner.clone();
        per_point.params.push(("remote_ratio".to_string(), r));
        let cells: Vec<Cell> = RATIO_SCENARIOS
            .iter()
            .map(|&scenario| Cell {
                app: registry::STRESS,
                scenario,
                num_cus: runner.cfg.num_cus,
            })
            .collect();
        legacy.extend(per_point.run_cells(&cells));
    }

    assert_eq!(generic.len(), legacy.len());
    for (g, l) in generic.iter().zip(&legacy) {
        assert_eq!(g.cell, l.cell);
        assert_eq!(g.seed, l.seed);
        assert_eq!(g.params, l.params);
        assert_eq!(g.remote_ratio, l.remote_ratio);
        assert_eq!(g.validated, l.validated);
        assert_eq!(
            format!("{:?}", g.result),
            format!("{:?}", l.result),
            "stats must match at r={:?}",
            g.remote_ratio
        );
    }
    // Byte-identical reports once the sweep's coordinate column (the
    // one schema addition of the refactor) is cleared.
    let mut stripped = generic.clone();
    for c in &mut stripped {
        c.axis_values = String::new();
    }
    assert_eq!(
        Report::from_cells(&stripped).to_csv(),
        Report::from_cells(&legacy).to_csv(),
        "remote-ratio sweep reports must be byte-identical to the legacy path"
    );
    assert_eq!(
        Report::from_cells(&stripped).to_json(),
        Report::from_cells(&legacy).to_json()
    );
}

/// The refactor's acceptance property, cu-count side: the generic
/// `run_sweep` must reproduce the deleted `run_cu_count_sweep` — CU-major
/// order, per-device-size seeds, no parameter overrides.
#[test]
fn single_axis_cu_count_equivalent_to_legacy_per_point_grids() {
    let points = [2u32, 4];
    let runner = tiny_runner();
    let plan = SweepPlan::new(registry::STRESS, &[axis::CU_COUNT])
        .unwrap()
        .with_points(axis::CU_COUNT, points.iter().map(|&n| f64::from(n)).collect())
        .unwrap();
    let generic = runner.run_sweep(&plan);

    let mut legacy = Vec::new();
    for &n in &points {
        let cells: Vec<Cell> = RATIO_SCENARIOS
            .iter()
            .map(|&scenario| Cell {
                app: registry::STRESS,
                scenario,
                num_cus: n,
            })
            .collect();
        legacy.extend(runner.run_cells(&cells));
    }

    assert_eq!(generic.len(), legacy.len());
    for (g, l) in generic.iter().zip(&legacy) {
        assert_eq!(g.cell, l.cell);
        assert_eq!(g.seed, l.seed, "per-device-size seed derivation must match");
        assert_eq!(g.validated, l.validated);
        assert_eq!(format!("{:?}", g.result), format!("{:?}", l.result));
    }
    let mut stripped = generic.clone();
    for c in &mut stripped {
        c.axis_values = String::new();
    }
    assert_eq!(
        Report::from_cells(&stripped).to_csv(),
        Report::from_cells(&legacy).to_csv(),
        "cu-count sweep reports must be byte-identical to the legacy path"
    );
}

#[test]
fn cli_composed_surface_long_format_csv() {
    let out = srsp_bin()
        .args(["sweep", "--axis", "remote-ratio,cu-count", "--size", "tiny"])
        .args(["--points", "remote-ratio=0,0.5", "--points", "cu-count=2,4"])
        .args(["--jobs", "2", "--report", "csv"])
        .output()
        .expect("spawn srsp");
    assert!(
        out.status.success(),
        "{}",
        String::from_utf8_lossy(&out.stderr)
    );
    let csv = String::from_utf8_lossy(&out.stdout);
    let lines: Vec<&str> = csv.lines().collect();
    assert_eq!(
        lines.len(),
        1 + 2 * 2 * 3,
        "header + 2 ratios × 2 CU counts × 3 protocols"
    );
    let columns = REPORT_SCHEMA.columns.len();
    assert_eq!(lines[0], REPORT_SCHEMA.columns.join(","));
    for line in &lines {
        assert_eq!(line.split(',').count(), columns, "ragged line: {line}");
    }
    // Long format: every row carries its full coordinate vector.
    for line in &lines[1..] {
        assert!(line.contains("remote-ratio="), "{line}");
        assert!(line.contains(";cu-count="), "{line}");
        assert!(line.contains(",true,"), "oracle-validated row: {line}");
    }
    assert!(csv.contains("remote-ratio=0.5;cu-count=4"));
}

#[test]
fn cli_registry_only_axes_run_end_to_end() {
    // hot-set and migration exist purely as axis-registry entries; both
    // must sweep from the CLI by name, oracle-gated, with their
    // coordinate in the report and the driven parameter in `params`.
    for (name, key) in [("hot-set", "hot_set"), ("migration", "migration")] {
        let out = srsp_bin()
            .args(["sweep", "--axis", name, "--size", "tiny", "--cus", "4"])
            .args(["--points", &format!("{name}=1,2"), "--jobs", "2"])
            .args(["--report", "csv"])
            .output()
            .expect("spawn srsp");
        assert!(
            out.status.success(),
            "{name}: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        let csv = String::from_utf8_lossy(&out.stdout);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 1 + 2 * 3, "{name}: header + 2 points × 3 protocols");
        for line in &lines[1..] {
            assert!(line.contains(",true,"), "{name} oracle row: {line}");
        }
        assert!(csv.contains(&format!("{name}=2")), "{name}: coordinate column");
        assert!(csv.contains(&format!("{key}=2")), "{name}: params column");
    }
}

#[test]
fn cli_rejects_duplicate_axes_and_orphan_points() {
    // Duplicate axes in --axis.
    let out = srsp_bin()
        .args(["sweep", "--axis", "cu-count,cu-count"])
        .output()
        .expect("spawn srsp");
    assert!(!out.status.success());
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("duplicate"),
        "the error must call out the duplicate axis"
    );
    // An alias duplicating its canonical name is the same axis.
    let out = srsp_bin()
        .args(["sweep", "--axis", "cu-count,cu"])
        .output()
        .expect("spawn srsp");
    assert!(!out.status.success());

    // --points for an axis the sweep does not compose.
    let out = srsp_bin()
        .args(["sweep", "--axis", "remote-ratio", "--points", "cu-count=4,8"])
        .args(["--size", "tiny"])
        .output()
        .expect("spawn srsp");
    assert!(!out.status.success());
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("cu-count"),
        "the error must name the orphan axis"
    );

    // --points repeated for one axis (also via a shorthand).
    let out = srsp_bin()
        .args(["sweep", "--axis", "remote-ratio", "--points", "remote-ratio=0"])
        .args(["--ratios", "0.5"])
        .output()
        .expect("spawn srsp");
    assert!(!out.status.success());
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("twice"),
        "the error must flag the repeated points"
    );

    // More than MAX_SWEEP_AXES composed axes.
    let out = srsp_bin()
        .args(["sweep", "--axis", "remote-ratio,cu-count,hot-set,migration"])
        .output()
        .expect("spawn srsp");
    assert!(!out.status.success());

    // Out-of-domain points fail at parse, not mid-run.
    let out = srsp_bin()
        .args(["sweep", "--axis", "remote-ratio", "--points", "remote-ratio=1.5"])
        .output()
        .expect("spawn srsp");
    assert!(!out.status.success());
    let out = srsp_bin()
        .args(["sweep", "--axis", "cu-count", "--points", "cu-count=2.5"])
        .output()
        .expect("spawn srsp");
    assert!(!out.status.success());
}

#[test]
fn cli_rejects_axis_flags_outside_sweep() {
    for cmd in [
        &["run", "--app", "stress", "--points", "remote-ratio=0.5"][..],
        &["validate", "--axis", "remote-ratio"][..],
        &["fig4", "--points", "hot-set=2"][..],
    ] {
        let out = srsp_bin().args(cmd).output().expect("spawn srsp");
        assert!(!out.status.success(), "{cmd:?} must be rejected");
    }
}

#[test]
fn cli_unknown_axis_lists_the_registered_ones() {
    let out = srsp_bin()
        .args(["sweep", "--axis", "bogus"])
        .output()
        .expect("spawn srsp");
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    for id in axis::all() {
        assert!(err.contains(id.name()), "error must list '{}':\n{err}", id.name());
    }
    assert!(err.contains("cus"), "error must mention the classic grid");
}

#[test]
fn cli_workload_without_the_driven_param_is_refused() {
    let out = srsp_bin()
        .args(["sweep", "--axis", "hot-set", "--app", "prk", "--size", "tiny"])
        .output()
        .expect("spawn srsp");
    assert!(!out.status.success());
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("has no hot_set parameter"),
        "the error must name the missing parameter"
    );
}
