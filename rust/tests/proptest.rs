//! Property tests for the word-level line data path and the scheduler's
//! byte-identity guarantee.
//!
//! The cache stores line data as eight 64-bit words and merges with
//! branchless mask expansion; these properties pin it against the
//! original per-byte formulation — a `[u8; 64]` model updated with the
//! exact loops the old code ran — under randomized masks, data, offsets
//! and op interleavings. The scheduler matrix pins the other tentpole:
//! a cost-skewed sweep produces byte-identical artifacts whether it
//! runs serially, on the work-stealing queue, or sharded across
//! subprocess-style partials.

use srsp::config::DeviceConfig;
use srsp::coordinator::{axis, shard, ExecutionPlan, Runner, Seeding, SweepPlan};
use srsp::harness::report::{PartialReport, Report};
use srsp::harness::runner::execute_shard;
use srsp::mem::{line_read, line_write, merge_masked, LineData, WcCache, ZERO_LINE};
use srsp::proptest::{run_prop, Gen};
use srsp::workload::registry::{self, WorkloadSize};

/// The pre-word-level line state: per-byte data with the per-byte merge
/// loops the cache used to run. The properties assert the word-wise
/// cache is observationally identical to this model.
#[derive(Clone)]
struct ByteLine {
    valid: u64,
    dirty: u64,
    data: [u8; 64],
}

impl ByteLine {
    fn new() -> Self {
        ByteLine { valid: 0, dirty: 0, data: [0; 64] }
    }

    /// The old `write_masked` inner loop: copy each selected byte.
    fn write_masked(&mut self, mask: u64, src: &[u8; 64]) {
        for i in 0..64 {
            if mask & (1 << i) != 0 {
                self.data[i] = src[i];
            }
        }
        self.valid |= mask;
        self.dirty |= mask;
    }

    /// The old `fill` inner loop: take fill bytes wherever not dirty.
    fn fill(&mut self, fill: &[u8; 64]) {
        for i in 0..64 {
            if self.dirty & (1 << i) == 0 {
                self.data[i] = fill[i];
            }
        }
        self.valid = u64::MAX;
    }
}

fn gen_bytes(g: &mut Gen) -> [u8; 64] {
    let mut b = [0u8; 64];
    for x in &mut b {
        *x = g.u64(0..256) as u8;
    }
    b
}

fn to_line_data(b: &[u8; 64]) -> LineData {
    let mut d = ZERO_LINE;
    for (i, &x) in b.iter().enumerate() {
        line_write(&mut d, i, 1, x as u64);
    }
    d
}

/// Read every byte the cache holds for `line` (None where invalid).
fn cache_bytes(c: &mut WcCache, line: u64) -> Vec<Option<u8>> {
    (0..64)
        .map(|i| c.probe_read(line, i, 1, 1 << i).map(|v| v as u8))
        .collect()
}

#[test]
fn word_merge_matches_per_byte_reference() {
    run_prop("word_merge_matches_per_byte_reference", 200, |g| {
        // One line, no eviction pressure, roomy sFIFO: the property is
        // about the merge arithmetic, not the replacement policy.
        let mut cache = WcCache::new(1, 1, 1024);
        let mut model = ByteLine::new();
        let line = 7u64;
        let ops = g.len(1..24);
        for _ in 0..ops {
            if g.chance(0.3) && model.valid != 0 {
                let bytes = gen_bytes(g);
                cache.fill(line, to_line_data(&bytes));
                model.fill(&bytes);
            } else {
                let mut mask = g.u64(0..u64::MAX) & g.u64(0..u64::MAX);
                if mask == 0 {
                    mask = 1 << g.u64(0..64);
                }
                let bytes = gen_bytes(g);
                cache.write_masked(line, mask, &to_line_data(&bytes));
                model.write_masked(mask, &bytes);
            }
            let got = cache_bytes(&mut cache, line);
            for i in 0..64 {
                let want = (model.valid & (1 << i) != 0).then(|| model.data[i]);
                assert_eq!(
                    got[i], want,
                    "byte {i} diverged from the per-byte model (seed {})",
                    g.seed
                );
            }
        }
    });
}

#[test]
fn line_read_write_matches_byte_array_reference() {
    run_prop("line_read_write_matches_byte_array_reference", 300, |g| {
        let mut words = ZERO_LINE;
        let mut bytes = [0u8; 64];
        for _ in 0..g.len(1..32) {
            let len = g.usize(1..9);
            let off = g.usize(0..64 - len + 1);
            if g.bool() {
                let v = g.u64(0..u64::MAX);
                line_write(&mut words, off, len, v);
                for k in 0..len {
                    bytes[off + k] = (v >> (8 * k)) as u8;
                }
            }
            let got = line_read(&words, off, len);
            let mut want = 0u64;
            for k in 0..len {
                want |= (bytes[off + k] as u64) << (8 * k);
            }
            assert_eq!(got, want, "off={off} len={len} (seed {})", g.seed);
        }
        // The whole-line views agree too.
        assert_eq!(to_line_data(&bytes), words, "seed {}", g.seed);
    });
}

#[test]
fn merge_masked_equals_per_byte_select() {
    run_prop("merge_masked_equals_per_byte_select", 300, |g| {
        let dst_bytes = gen_bytes(g);
        let src_bytes = gen_bytes(g);
        let mask = g.u64(0..u64::MAX);
        let mut dst = to_line_data(&dst_bytes);
        merge_masked(&mut dst, &to_line_data(&src_bytes), mask);
        let mut want = dst_bytes;
        for i in 0..64 {
            if mask & (1 << i) != 0 {
                want[i] = src_bytes[i];
            }
        }
        assert_eq!(dst, to_line_data(&want), "mask={mask:#018x} (seed {})", g.seed);
    });
}

/// A deliberately cost-skewed plan: the CU-count axis spans 2..8, so
/// cell wall time varies by roughly the CU ratio — exactly the shape
/// the static deal loses on and the stealing queue rebalances.
fn skewed_sweep() -> SweepPlan {
    SweepPlan::new(registry::STRESS, &[axis::REMOTE_RATIO, axis::CU_COUNT])
        .unwrap()
        .with_points(axis::REMOTE_RATIO, vec![0.0, 0.5, 1.0])
        .unwrap()
        .with_points(axis::CU_COUNT, vec![2.0, 4.0, 8.0])
        .unwrap()
}

fn skewed_runner(jobs: usize) -> Runner {
    Runner {
        seeding: Seeding::PerCell(11),
        validate: true,
        ..Runner::new(
            DeviceConfig { num_cus: 4, ..DeviceConfig::small() },
            WorkloadSize::Tiny,
            jobs,
        )
    }
}

#[test]
fn scheduler_matrix_is_byte_identical() {
    // --jobs 1 (serial), --jobs 4 (work-stealing queue), and a
    // 2-partition subprocess-style execution of the same plan must all
    // emit byte-identical artifacts on the cost-skewed sweep.
    let sweep = skewed_sweep();
    let serial = skewed_runner(1).run_sweep(&sweep);
    let stolen = skewed_runner(4).run_sweep(&sweep);
    assert_eq!(format!("{serial:?}"), format!("{stolen:?}"));
    let a = Report::from_cells(&serial);
    let b = Report::from_cells(&stolen);
    assert_eq!(a.to_csv(), b.to_csv(), "--jobs 4 must not change the CSV");
    assert_eq!(a.to_json(), b.to_json(), "--jobs 4 must not change the JSON");

    let plan = ExecutionPlan::lower_sweep(&skewed_runner(1), &sweep);
    let partials: Vec<PartialReport> = shard::partition(&plan, 2)
        .iter()
        .map(|s| PartialReport::from_shard(s, &execute_shard(s)))
        .map(|p| PartialReport::from_json(&p.to_json()).expect("partial round-trip"))
        .collect();
    let merged = Report::merge(&partials).unwrap();
    assert_eq!(merged.to_csv(), a.to_csv(), "--workers 2 must not change the CSV");
    assert_eq!(merged.to_json(), a.to_json(), "--workers 2 must not change the JSON");
}
