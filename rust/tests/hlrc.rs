//! hLRC extension-protocol tests (the paper's §6 closest related work):
//! lazy ownership transfer must preserve all the correctness properties
//! the RSP protocols provide, with its own cost profile (transfer
//! ping-pong, registry pressure).

use srsp::config::{DeviceConfig, Protocol, Scenario};
use srsp::gpu::Device;
use srsp::kir::{Asm, Src};
use srsp::mem::{BackingStore, MemAlloc};
use srsp::proptest::{run_prop, Gen};
use srsp::sync::{AtomicOp, MemOrder, Scope};
use srsp::workload::driver::run_scenario_seeded;
use srsp::workload::engine::NativeMath;
use srsp::workload::graph::Graph;
use srsp::workload::mis::Mis;
use srsp::workload::pagerank::PageRank;
use srsp::workload::sssp::Sssp;

/// Lock handoff: both sharers use plain wg-scope ops; hLRC's lazy
/// transfer must provide exclusion and visibility.
#[test]
fn hlrc_lock_handoff_exact() {
    const LOCK: u64 = 0x1000;
    const DATA: u64 = 0x2000;
    for (n0, n1) in [(1u64, 1u64), (10, 3), (40, 15)] {
        let mut a = Asm::new();
        let wg = a.reg();
        let lock = a.reg();
        let data = a.reg();
        let old = a.reg();
        let tmp = a.reg();
        let i = a.reg();
        let c = a.reg();
        a.wg_id(wg);
        a.imm(lock, LOCK);
        a.imm(data, DATA);
        a.imm(i, 0);
        // Both sides run the SAME wg-scope code: hLRC hides the sharing.
        a.label("loop");
        a.eq(c, wg, Src::I(0));
        a.bnz(c, "limit0");
        a.lt_u(c, i, Src::I(n1));
        a.br("limited");
        a.label("limit0");
        a.lt_u(c, i, Src::I(n0));
        a.label("limited");
        a.bz(c, "done");
        a.label("spin");
        a.atomic(old, AtomicOp::Cas, lock, Src::I(1), Src::I(0), MemOrder::Acquire, Scope::Wg);
        a.bnz(old, "spin");
        a.ld(tmp, data, 0, 4);
        a.add(tmp, tmp, Src::I(1));
        a.st(data, 0, tmp, 4);
        a.atomic(old, AtomicOp::Store, lock, Src::I(0), Src::I(0), MemOrder::Release, Scope::Wg);
        a.add(i, i, Src::I(1));
        a.br("loop");
        a.label("done");
        a.halt();
        let prog = a.finish();

        let mut dev = Device::new(DeviceConfig::small(), Protocol::HLRC);
        dev.launch_simple(&prog, 2);
        assert_eq!(
            dev.mem.backing.read_u32(DATA) as u64,
            n0 + n1,
            "hLRC ({n0},{n1}): mutual exclusion must hold"
        );
        assert!(
            dev.mem.stats.misc.get("hlrc_transfers").copied().unwrap_or(0) > 0,
            "ownership must actually ping-pong"
        );
    }
}

#[test]
fn hlrc_workloads_validate_against_oracles() {
    let cfg = DeviceConfig::small();

    let g = Graph::small_world(128, 4, 0.2, 11);
    let oracle = PageRank::oracle(&g, 3);
    let mut alloc = MemAlloc::new();
    let mut image = BackingStore::new();
    let mut prk = PageRank::setup(&g, &mut alloc, &mut image, 8, 3);
    let (run, mem) = run_scenario_seeded(&cfg, Scenario::HLRC, &mut prk, NativeMath, 16, image);
    assert!(run.converged);
    let d: f32 = prk
        .result(&mem)
        .iter()
        .zip(&oracle)
        .map(|(a, b)| (a - b).abs())
        .sum();
    assert!(d < 1e-4, "hLRC PageRank deviates by {d}");

    let g = Graph::road_grid(10, 10, 2);
    let oracle = Sssp::oracle(&g, 0);
    let mut alloc = MemAlloc::new();
    let mut image = BackingStore::new();
    let mut sssp = Sssp::setup(&g, &mut alloc, &mut image, 8, 0);
    let (run, mem) = run_scenario_seeded(&cfg, Scenario::HLRC, &mut sssp, NativeMath, 400, image);
    assert!(run.converged);
    assert_eq!(sssp.result(&mem), oracle, "hLRC SSSP must be exact");

    let g = Graph::power_law(128, 2, 4);
    let mut alloc = MemAlloc::new();
    let mut image = BackingStore::new();
    let mut mis = Mis::setup(&g, &mut alloc, &mut image, 8);
    let (run, mem) = run_scenario_seeded(&cfg, Scenario::HLRC, &mut mis, NativeMath, 64, image);
    assert!(run.converged);
    let state = mis.result(&mem);
    Mis::validate_mis(&g, &state).unwrap();
    assert_eq!(state, Mis::oracle(&g));
}

/// Counter uniqueness under randomized owner/thief claim storms.
#[test]
fn hlrc_claim_counter_never_double_claims() {
    run_prop("hlrc_claims", 25, |g: &mut Gen| {
        const CTR: u64 = 0x1000;
        let count = g.u64(1..60);
        let mut a = Asm::new();
        let wg = a.reg();
        let ctr = a.reg();
        let i = a.reg();
        let c = a.reg();
        let addr = a.reg();
        let one = a.reg();
        a.wg_id(wg);
        a.imm(ctr, CTR);
        a.imm(one, 1);
        a.label("loop");
        a.atomic(i, AtomicOp::Add, ctr, Src::I(1), Src::I(0), MemOrder::AcqRel, Scope::Wg);
        a.ge_u(c, i, Src::I(count));
        a.bnz(c, "done");
        // claimed[i] += 1 (exclusive by construction)
        a.shl(addr, i, Src::I(2));
        a.add(addr, addr, Src::I(0x8000));
        a.ld(c, addr, 0, 4);
        a.add(c, c, Src::R(one));
        a.st(addr, 0, c, 4);
        a.br("loop");
        a.label("done");
        a.halt();
        let prog = a.finish();

        let nwgs = g.u32(2..5);
        let mut dev = Device::new(DeviceConfig::small(), Protocol::HLRC);
        dev.launch_simple(&prog, nwgs);
        for k in 0..count {
            let v = dev.mem.backing.read_u32(0x8000 + k * 4);
            assert_eq!(v, 1, "claim {k} taken {v} times (count={count}, wgs={nwgs})");
        }
    });
}

/// Registry eviction pressure: more sync variables than registry entries
/// must stay correct (evicted owners flush).
#[test]
fn hlrc_registry_eviction_correct() {
    // small() has 4 CUs -> registry capacity 8; use 24 counters.
    const BASE: u64 = 0x10000;
    let mut a = Asm::new();
    let wg = a.reg();
    let addr = a.reg();
    let i = a.reg();
    let c = a.reg();
    let old = a.reg();
    a.wg_id(wg);
    a.imm(i, 0);
    a.label("loop");
    // addr = BASE + ((i + wg*7) % 24) * 64
    a.add(c, i, Src::R(wg));
    a.mul(c, c, Src::I(7));
    a.alu(srsp::kir::AluOp::RemU, c, c, Src::I(24));
    a.shl(addr, c, Src::I(6));
    a.add(addr, addr, Src::I(BASE));
    a.atomic(old, AtomicOp::Add, addr, Src::I(1), Src::I(0), MemOrder::AcqRel, Scope::Wg);
    a.add(i, i, Src::I(1));
    a.lt_u(c, i, Src::I(30));
    a.bnz(c, "loop");
    a.halt();
    let prog = a.finish();

    let mut dev = Device::new(DeviceConfig::small(), Protocol::HLRC);
    dev.launch_simple(&prog, 4);
    // Every increment must land: total = 4 wgs * 30.
    let mut total = 0u64;
    for k in 0..24u64 {
        total += dev.mem.backing.read_u32(BASE + k * 64) as u64;
    }
    assert_eq!(total, 4 * 30, "registry eviction lost increments");
    dev.mem.check_invariants();
}
