//! Registry round-trip: every registered workload is runnable **by
//! name** from the CLI — the registry is the single source of truth for
//! workload dispatch, and no app enum exists outside it. These tests
//! drive the actual `srsp` binary so the whole chain (name resolution,
//! parameter handling, preset construction, scenario run) is covered
//! end-to-end.

use std::process::Command;

use srsp::workload::registry;

fn srsp_bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_srsp"))
}

#[test]
fn registry_holds_seven_workloads() {
    assert_eq!(registry::all().count(), 7);
    for name in ["prk", "sssp", "mis", "stress", "bfs", "prodcons", "lock"] {
        assert!(registry::resolve(name).is_some(), "{name} must resolve");
    }
}

#[test]
fn list_workloads_covers_the_registry() {
    let out = srsp_bin().arg("list-workloads").output().expect("spawn srsp");
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for id in registry::all() {
        assert!(
            text.contains(id.name()),
            "'{}' missing from list-workloads:\n{text}",
            id.name()
        );
    }
}

#[test]
fn every_workload_runs_by_name_from_the_cli() {
    for id in registry::all() {
        let out = srsp_bin()
            .args(["run", "--app", id.name(), "--size", "tiny", "--cus", "4"])
            .output()
            .expect("spawn srsp");
        assert!(
            out.status.success(),
            "srsp run --app {} failed:\n{}",
            id.name(),
            String::from_utf8_lossy(&out.stderr)
        );
        let text = String::from_utf8_lossy(&out.stdout);
        assert!(text.contains("converged=true"), "{}:\n{text}", id.name());
    }
}

#[test]
fn unknown_workload_name_lists_the_registered_ones() {
    let out = srsp_bin()
        .args(["run", "--app", "bogus"])
        .output()
        .expect("spawn srsp");
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    for id in registry::all() {
        assert!(err.contains(id.name()), "error must list '{}':\n{err}", id.name());
    }
}

#[test]
fn params_reach_the_kernel_and_unknown_keys_fail() {
    let out = srsp_bin()
        .args(["run", "--app", "stress", "--size", "tiny", "--cus", "4"])
        .args(["--param", "rounds=2", "--param", "tasks=64"])
        .output()
        .expect("spawn srsp");
    assert!(
        out.status.success(),
        "{}",
        String::from_utf8_lossy(&out.stderr)
    );

    let out = srsp_bin()
        .args(["run", "--app", "stress", "--param", "bogus=1"])
        .output()
        .expect("spawn srsp");
    assert!(!out.status.success());
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("unknown parameter"),
        "the error must name the bad key"
    );
}
