//! The distributed sweep pipeline end to end: plan → shard → execute →
//! merge, with the serializable stage boundaries exercised both at the
//! library level and through the real `srsp worker` / `merge-reports` /
//! `sweep --workers` CLI. The acceptance property throughout: a plan
//! executed by worker subprocesses merges to a report **byte-identical**
//! to the same plan run in-process, for any worker count — and every
//! failure path (dead worker, truncated partial, version drift) fails
//! loudly instead of producing a short report.

use std::path::PathBuf;
use std::process::Command;

use srsp::config::DeviceConfig;
use srsp::coordinator::{axis, shard, ExecutionPlan, Runner, Seeding, SweepPlan};
use srsp::harness::presets::WorkloadSize;
use srsp::harness::report::{PartialReport, Report};
use srsp::harness::runner::execute_shard;
use srsp::workload::registry;

fn srsp_bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_srsp"))
}

/// A scratch directory unique to this test process + test name.
fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("srsp-test-{}-{name}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("create scratch dir");
    dir
}

fn tiny_runner() -> Runner {
    Runner {
        validate: true,
        seeding: Seeding::PerCell(11),
        ..Runner::new(
            DeviceConfig {
                num_cus: 4,
                ..DeviceConfig::small()
            },
            WorkloadSize::Tiny,
            4,
        )
    }
}

fn surface_plan() -> SweepPlan {
    SweepPlan::new(registry::STRESS, &[axis::REMOTE_RATIO, axis::CU_COUNT])
        .unwrap()
        .with_points(axis::REMOTE_RATIO, vec![0.0, 0.5])
        .unwrap()
        .with_points(axis::CU_COUNT, vec![2.0, 4.0])
        .unwrap()
}

/// Library level: shard partitioning is a pure function of (plan, N) and
/// the stage-boundary files reproduce it exactly.
#[test]
fn shard_partition_deterministic_across_lowering_and_files() {
    let runner = tiny_runner();
    let plan = surface_plan();
    let lowered_a = ExecutionPlan::lower_sweep(&runner, &plan);
    let lowered_b = ExecutionPlan::lower_sweep(&runner, &plan);
    assert_eq!(lowered_a, lowered_b, "lowering must be deterministic");
    for n in [1, 2, 4, 7] {
        let shards_a = shard::partition(&lowered_a, n);
        let shards_b = shard::partition(&lowered_b, n);
        assert_eq!(shards_a, shards_b, "partition({n}) must be deterministic");
        for (s_a, s_b) in shards_a.iter().zip(&shards_b) {
            assert_eq!(s_a.to_json(), s_b.to_json(), "shard files must be identical");
            assert_eq!(&shard::ShardSpec::from_json(&s_a.to_json()).unwrap(), s_a);
        }
    }
}

/// Library level: executing the shards separately and merging the
/// JSON-round-tripped partials reproduces the in-process sweep report
/// byte for byte, for 1, 2 and 4 workers — and the in-process report is
/// itself --jobs-independent.
#[test]
fn merged_sweep_report_byte_identical_to_in_process() {
    let plan = surface_plan();
    let jobs1 = Report::from_cells(&Runner { jobs: 1, ..tiny_runner() }.run_sweep(&plan));
    let jobs4 = Report::from_cells(&Runner { jobs: 4, ..tiny_runner() }.run_sweep(&plan));
    assert_eq!(jobs1.to_csv(), jobs4.to_csv(), "--jobs must not change the report");
    assert_eq!(jobs1.to_json(), jobs4.to_json());

    let lowered = ExecutionPlan::lower_sweep(&tiny_runner(), &plan);
    for workers in [1, 2, 4] {
        let partials: Vec<PartialReport> = shard::partition(&lowered, workers)
            .iter()
            .map(|s| PartialReport::from_shard(s, &execute_shard(s)))
            .map(|p| PartialReport::from_json(&p.to_json()).expect("lossless partial"))
            .collect();
        let merged = Report::merge(&partials).unwrap();
        assert_eq!(merged.to_csv(), jobs1.to_csv(), "{workers} workers (csv)");
        assert_eq!(merged.to_json(), jobs1.to_json(), "{workers} workers (json)");
    }
}

/// CLI level, the acceptance gate: `sweep --workers 2` (subprocess
/// executors) emits a report byte-identical to the same plan via
/// `--jobs 4` and `--jobs 1` in-process.
#[test]
fn cli_workers_report_byte_identical_to_jobs() {
    let dir = scratch("workers-vs-jobs");
    let run = |mode: &[&str], out: &PathBuf, format: &str| {
        let status = srsp_bin()
            .args(["sweep", "--axis", "remote-ratio,cu-count", "--app", "stress"])
            .args(["--size", "tiny", "--seed", "11"])
            .args(["--points", "remote-ratio=0,0.5", "--points", "cu-count=2,4"])
            .args(mode)
            .args(["--report", format, "--out", out.to_str().unwrap()])
            .status()
            .expect("spawn srsp");
        assert!(status.success(), "sweep {mode:?} failed");
    };
    let (w2, j4, j1) = (dir.join("w2.csv"), dir.join("j4.csv"), dir.join("j1.csv"));
    run(&["--workers", "2"], &w2, "csv");
    run(&["--jobs", "4"], &j4, "csv");
    run(&["--jobs", "1"], &j1, "csv");
    let (w2, j4, j1) = (
        std::fs::read(&w2).unwrap(),
        std::fs::read(&j4).unwrap(),
        std::fs::read(&j1).unwrap(),
    );
    assert!(!w2.is_empty());
    assert_eq!(w2, j4, "--workers 2 must be byte-identical to --jobs 4");
    assert_eq!(w2, j1, "--workers 2 must be byte-identical to --jobs 1");
    // And for the JSON report format too.
    let (w2j, j1j) = (dir.join("w2.json"), dir.join("j1.json"));
    run(&["--workers", "3"], &w2j, "json");
    run(&["--jobs", "2"], &j1j, "json");
    assert_eq!(std::fs::read(&w2j).unwrap(), std::fs::read(&j1j).unwrap());
    let _ = std::fs::remove_dir_all(&dir);
}

/// CLI level: a hand-driven pipeline — shard files in, `srsp worker` per
/// shard, `srsp merge-reports` over the partials — reassembles the exact
/// in-process report (the multi-host transport story: every stage
/// boundary is a file).
#[test]
fn cli_worker_and_merge_reports_reassemble_the_run() {
    let dir = scratch("worker-merge");
    let runner = tiny_runner();
    let plan = surface_plan();
    let expect = Report::from_cells(&runner.run_sweep(&plan));
    let lowered = ExecutionPlan::lower_sweep(&runner, &plan);
    let shards = shard::partition(&lowered, 2);
    let mut merge = srsp_bin();
    merge.arg("merge-reports");
    for spec in &shards {
        let shard_path = dir.join(format!("shard-{}.json", spec.shard));
        std::fs::write(&shard_path, spec.to_json()).unwrap();
        // Worker writes its partial to --out; stdout stays clean.
        let partial_path = dir.join(format!("partial-{}.json", spec.shard));
        let out = srsp_bin()
            .args(["worker", "--shard", shard_path.to_str().unwrap()])
            .args(["--out", partial_path.to_str().unwrap()])
            .output()
            .expect("spawn worker");
        assert!(
            out.status.success(),
            "worker {}: {}",
            spec.shard,
            String::from_utf8_lossy(&out.stderr)
        );
        let partial =
            PartialReport::from_json(&std::fs::read_to_string(&partial_path).unwrap()).unwrap();
        assert_eq!(partial.shard, spec.shard);
        assert_eq!(partial.rows.len(), spec.cells.len());
        merge.args(["--partial", partial_path.to_str().unwrap()]);
    }
    // Without --out, a worker streams the partial to stdout.
    let out = srsp_bin()
        .args(["worker", "--shard", dir.join("shard-0.json").to_str().unwrap()])
        .output()
        .expect("spawn worker");
    assert!(out.status.success());
    let streamed = PartialReport::from_json(&String::from_utf8_lossy(&out.stdout)).unwrap();
    assert_eq!(streamed.shard, 0);

    let merged_path = dir.join("merged.csv");
    let out = merge
        .args(["--report", "csv", "--out", merged_path.to_str().unwrap()])
        .output()
        .expect("spawn merge-reports");
    assert!(
        out.status.success(),
        "merge-reports: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert_eq!(
        std::fs::read_to_string(&merged_path).unwrap(),
        expect.to_csv(),
        "merge-reports must reassemble the exact in-process report"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Failure paths: a dead/confused worker or a truncated partial fails
/// the pipeline loudly — never a short report.
#[test]
fn cli_failure_paths_are_loud() {
    let dir = scratch("failures");
    // A worker pointed at a missing shard file exits non-zero.
    let out = srsp_bin()
        .args(["worker", "--shard", dir.join("nope.json").to_str().unwrap()])
        .output()
        .expect("spawn worker");
    assert!(!out.status.success());
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("error"),
        "missing shard file must be reported"
    );
    // A malformed shard file exits non-zero naming the problem.
    let bad = dir.join("bad.json");
    std::fs::write(&bad, "{\"plan_version\":999}").unwrap();
    let out = srsp_bin()
        .args(["worker", "--shard", bad.to_str().unwrap()])
        .output()
        .expect("spawn worker");
    assert!(!out.status.success());
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("version"),
        "version drift must be named"
    );
    // merge-reports with a missing worker's partial: loud, non-zero.
    let runner = tiny_runner();
    let lowered = ExecutionPlan::lower_sweep(&runner, &surface_plan());
    let shards = shard::partition(&lowered, 2);
    let p0 = PartialReport::from_shard(&shards[0], &execute_shard(&shards[0]));
    let p0_path = dir.join("p0.json");
    std::fs::write(&p0_path, p0.to_json()).unwrap();
    let out = srsp_bin()
        .args(["merge-reports", "--partial", p0_path.to_str().unwrap()])
        .output()
        .expect("spawn merge-reports");
    assert!(!out.status.success(), "half a run must not merge");
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("worker is missing"),
        "the gap must be named"
    );
    // A truncated partial report (worker died mid-write): loud.
    let truncated = p0.to_json();
    let truncated = &truncated[..truncated.len() / 2];
    let trunc_path = dir.join("trunc.json");
    std::fs::write(&trunc_path, truncated).unwrap();
    let out = srsp_bin()
        .args(["merge-reports", "--partial", trunc_path.to_str().unwrap()])
        .output()
        .expect("spawn merge-reports");
    assert!(!out.status.success(), "a truncated partial must not merge");
    // Library level: a partial whose rows were cut short (valid JSON,
    // incomplete coverage) fails the merge naming the gap.
    let mut short = PartialReport::from_shard(&shards[1], &execute_shard(&shards[1]));
    short.rows.pop();
    let err = Report::merge(&[p0, short]).unwrap_err();
    assert!(err.contains("truncated"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// The distributed-pipeline flags are each scoped to one command.
#[test]
fn cli_rejects_misplaced_distributed_flags() {
    for (args, needle) in [
        (vec!["run", "--workers", "2"], "--workers applies to"),
        (vec!["ci-smoke", "--workers", "2"], "--workers applies to"),
        (
            vec!["sweep", "--workers", "2"], // classic --axis cus default
            "registry-axis sweeps",
        ),
        (
            vec!["sweep", "--axis", "remote-ratio", "--workers", "2", "--jobs", "4"],
            "pick one",
        ),
        (vec!["sweep", "--axis", "remote-ratio", "--shard", "x"], "--shard applies to"),
        (vec!["run", "--partial", "x"], "--partial applies to"),
        (vec!["worker"], "--shard"),
        (vec!["merge-reports"], "--partial"),
        (vec!["sweep", "--workers", "0"], "at least 1"),
    ] {
        let out = srsp_bin().args(&args).output().expect("spawn srsp");
        assert!(!out.status.success(), "{args:?} must be rejected");
        let stderr = String::from_utf8_lossy(&out.stderr);
        assert!(stderr.contains(needle), "{args:?}: expected '{needle}' in:\n{stderr}");
    }
}

/// Satellite: the first proto-param sweep axis. `lr-tbl-entries` drives
/// `CellSpec::proto_params` through the registry — table pressure rises
/// as the swept capacity shrinks, and the coordinate lands in both the
/// axis_values and proto_params report columns.
#[test]
fn lr_tbl_entries_axis_sweeps_table_pressure() {
    let runner = tiny_runner();
    let plan = SweepPlan::new(registry::STRESS, &[axis::LR_TBL_ENTRIES])
        .unwrap()
        .with_points(axis::LR_TBL_ENTRIES, vec![1.0, 16.0])
        .unwrap();
    let results = runner.run_sweep(&plan);
    assert_eq!(results.len(), 2 * plan.scenarios.len());
    let srsp_cells: Vec<_> = results
        .iter()
        .filter(|c| c.cell.scenario.name() == "srsp")
        .collect();
    assert_eq!(srsp_cells.len(), 2);
    for c in &results {
        assert_eq!(c.validated, Some(true), "{}", c.axis_values);
        assert!(c.params.is_empty(), "a proto-param axis must not touch --param");
    }
    // The swept capacity reaches the device: a 1-entry LR-TBL overflows,
    // and pressure does not decrease as capacity grows to the default.
    let (tiny, full) = (&srsp_cells[0], &srsp_cells[1]);
    assert_eq!(tiny.proto_params, "lr_tbl_entries=1");
    assert_eq!(full.proto_params, "lr_tbl_entries=16");
    assert!(tiny.result.stats.lr_tbl_overflows > 0, "1-entry table must overflow");
    assert!(tiny.result.stats.lr_tbl_overflows >= full.result.stats.lr_tbl_overflows);
    // Non-sRSP protocols ignore the key and report nothing.
    let steal = results.iter().find(|c| c.cell.scenario.name() == "steal").unwrap();
    assert_eq!(steal.proto_params, "");
    assert_eq!(steal.axis_values, "lr-tbl-entries=1");
}

/// The same axis from the CLI, by registry name — including under
/// `--workers`, since a proto-param axis must cross the worker boundary.
#[test]
fn cli_lr_tbl_entries_axis_end_to_end() {
    let dir = scratch("lr-tbl-cli");
    let out_path = dir.join("lr.csv");
    let out = srsp_bin()
        .args(["sweep", "--axis", "lr-tbl-entries", "--app", "stress"])
        .args(["--size", "tiny", "--cus", "4", "--points", "lr-tbl-entries=1,16"])
        .args(["--workers", "2"])
        .args(["--report", "csv", "--out", out_path.to_str().unwrap()])
        .output()
        .expect("spawn srsp");
    assert!(
        out.status.success(),
        "{}",
        String::from_utf8_lossy(&out.stderr)
    );
    let csv = std::fs::read_to_string(&out_path).unwrap();
    // Comma-anchored: "lr-tbl-entries=1" alone would also match =16 rows.
    assert!(csv.contains("lr-tbl-entries=1,"), "axis coordinate column:\n{csv}");
    assert!(csv.contains("lr-tbl-entries=16,"));
    assert!(csv.contains("lr_tbl_entries=1,"), "proto_params column:\n{csv}");
    for line in csv.lines().skip(1) {
        assert!(line.contains(",true,"), "oracle-validated row: {line}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}
