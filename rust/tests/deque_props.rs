//! Work-stealing queue safety properties: under randomized queue sizes,
//! thief counts and protocols, every task is claimed exactly once (no
//! loss, no duplication), verified per-task via claim counters in
//! simulated memory.

use srsp::config::{DeviceConfig, Protocol, Scenario};
use srsp::gpu::Device;
use srsp::kir::{Asm, Src};
use srsp::mem::MemAlloc;
use srsp::proptest::{run_prop, Gen};
use srsp::workload::deque::{
    emit_advertise_empty, emit_owner_pop, emit_steal, DequeLayout, DequeRegs, SyncFlavor, EMPTY,
};

/// Kernel: wg q drains its own queue; when empty, scans every other queue
/// stealing. Each claimed task id `t` bumps `claimed[t]` (claimer-private
/// write: claims are exclusive, so no race).
fn kernel(
    layout: &DequeLayout,
    flavor: SyncFlavor,
    claimed: u64,
    num_wgs: u32,
) -> srsp::kir::Program {
    let mut a = Asm::new();
    let qbase = a.reg();
    let task = a.reg();
    let t0 = a.reg();
    let t1 = a.reg();
    let t2 = a.reg();
    let wg = a.reg();
    let addr = a.reg();
    let victim = a.reg();
    let one = a.reg();

    a.wg_id(wg);
    a.imm(one, 1);
    a.imm(t0, layout.stride);
    a.mul(qbase, wg, Src::R(t0));
    a.add(qbase, qbase, Src::I(layout.base));
    let regs = DequeRegs { qbase, task, t0, t1, t2 };

    a.label("own");
    emit_owner_pop(&mut a, &regs, flavor, "o");
    a.eq(t0, task, Src::I(EMPTY));
    a.bnz(t0, "own_done");
    a.shl(addr, task, Src::I(2));
    a.add(addr, addr, Src::I(claimed));
    a.ld(t1, addr, 0, 4);
    a.add(t1, t1, Src::R(one));
    a.st(addr, 0, t1, 4);
    a.br("own");
    a.label("own_done");
    emit_advertise_empty(&mut a, &regs);

    // Steal sweep over all other queues.
    a.add(victim, wg, Src::I(1));
    a.label("scan");
    a.alu(srsp::kir::AluOp::RemU, victim, victim, Src::I(num_wgs as u64));
    a.eq(t0, victim, Src::R(wg));
    a.bnz(t0, "end");
    a.imm(t0, layout.stride);
    a.mul(qbase, victim, Src::R(t0));
    a.add(qbase, qbase, Src::I(layout.base));
    a.label("steal");
    emit_steal(&mut a, &regs, flavor, "s");
    a.eq(t0, task, Src::I(EMPTY));
    a.bnz(t0, "next");
    a.shl(addr, task, Src::I(2));
    a.add(addr, addr, Src::I(claimed));
    a.ld(t1, addr, 0, 4);
    a.add(t1, t1, Src::R(one));
    a.st(addr, 0, t1, 4);
    a.br("steal");
    a.label("next");
    a.add(victim, victim, Src::I(1));
    a.br("scan");
    a.label("end");
    a.halt();
    a.finish()
}

fn check(g: &mut Gen, protocol: Protocol, scenario: Scenario) {
    let num_wgs = g.u32(2..5);
    let cfg = DeviceConfig {
        num_cus: 4,
        ..DeviceConfig::small()
    };
    let mut alloc = MemAlloc::new();
    let cap = g.u32(1..40);
    let layout = DequeLayout::alloc(&mut alloc, num_wgs, cap);
    // Unique global task ids across queues.
    let mut next_id = 0u32;
    let fills: Vec<Vec<u32>> = (0..num_wgs)
        .map(|_| {
            let n = g.usize(0..cap as usize + 1);
            (0..n)
                .map(|_| {
                    let id = next_id;
                    next_id += 1;
                    id
                })
                .collect()
        })
        .collect();
    let total = next_id;
    let claimed = alloc.alloc(total.max(1) as u64 * 4);

    let mut dev = Device::new(cfg, protocol);
    for (q, tasks) in fills.iter().enumerate() {
        layout.fill(&mut dev.mem.backing, q as u32, tasks);
    }
    let flavor = SyncFlavor::of(scenario);
    dev.launch_simple(&kernel(&layout, flavor, claimed, num_wgs), num_wgs);

    for t in 0..total {
        let c = dev.mem.backing.read_u32(claimed + t as u64 * 4);
        assert_eq!(
            c, 1,
            "{scenario:?}: task {t} claimed {c} times (wgs={num_wgs}, total={total})"
        );
    }
    for q in 0..num_wgs {
        assert_eq!(layout.remaining(&dev.mem.backing, q), 0, "queue {q} has leftovers");
    }
    dev.mem.check_invariants();
}

#[test]
fn every_task_claimed_exactly_once_srsp() {
    run_prop("deque_once_srsp", 30, |g| {
        check(g, Protocol::SRSP, Scenario::SRSP);
    });
}

#[test]
fn every_task_claimed_exactly_once_naive_rsp() {
    run_prop("deque_once_rsp", 30, |g| {
        check(g, Protocol::RSP_NAIVE, Scenario::RSP);
    });
}

#[test]
fn every_task_claimed_exactly_once_global() {
    run_prop("deque_once_steal", 30, |g| {
        check(g, Protocol::SCOPED_ONLY, Scenario::STEAL_ONLY);
    });
}
