//! The sweep service end to end: `srsp serve` / `srsp work` /
//! `srsp submit` over loopback TCP. The acceptance properties: a sweep
//! submitted to a coordinator merges **byte-identical** to the same
//! sweep run locally with `--jobs 1`; a worker killed mid-shard is
//! survived by retry/re-dispatch with no gap; a warm-cache resubmit
//! dispatches zero batches; a wire-version mismatch or malformed frame
//! is refused loudly; and every service flag is scoped to its command
//! through the declarative registry.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::mpsc;

use srsp::config::DeviceConfig;
use srsp::coordinator::cache::CacheCounters;
use srsp::coordinator::wire::Envelope;
use srsp::coordinator::{axis, shard, ExecutionPlan, Runner, Seeding, SweepPlan};
use srsp::harness::presets::WorkloadSize;
use srsp::harness::report::{PartialReport, Report};
use srsp::harness::runner::execute_shard;
use srsp::workload::registry;

fn srsp_bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_srsp"))
}

/// A scratch directory unique to this test process + test name.
fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("srsp-serve-{}-{name}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("create scratch dir");
    dir
}

fn tiny_runner() -> Runner {
    Runner {
        validate: true,
        seeding: Seeding::PerCell(11),
        ..Runner::new(
            DeviceConfig {
                num_cus: 4,
                ..DeviceConfig::small()
            },
            WorkloadSize::Tiny,
            1,
        )
    }
}

fn ratio_plan() -> SweepPlan {
    SweepPlan::new(registry::STRESS, &[axis::REMOTE_RATIO])
        .unwrap()
        .with_points(axis::REMOTE_RATIO, vec![0.0, 0.5])
        .unwrap()
}

/// The CLI flags that select the same sweep as [`ratio_plan`] under
/// [`tiny_runner`]'s config — shared by the local `sweep` reference and
/// the `submit` runs so byte-identity compares like with like.
const SWEEP_FLAGS: &[&str] = &[
    "--axis",
    "remote-ratio",
    "--app",
    "stress",
    "--size",
    "tiny",
    "--cus",
    "4",
    "--seed",
    "11",
    "--points",
    "remote-ratio=0,0.5",
];

/// Run the reference sweep locally with `--jobs 1` and return the CSV
/// report bytes.
fn local_reference(dir: &Path) -> Vec<u8> {
    let out = dir.join("local.csv");
    let status = srsp_bin()
        .arg("sweep")
        .args(SWEEP_FLAGS)
        .args(["--jobs", "1", "--report", "csv", "--out", out.to_str().unwrap()])
        .status()
        .expect("spawn local sweep");
    assert!(status.success(), "local reference sweep failed");
    std::fs::read(&out).expect("read local reference")
}

/// A running `srsp serve` child: its announced address, plus the stderr
/// split into the lines consumed while finding the address and a
/// channel carrying the rest at exit. Killed on drop so a failing test
/// never leaves a listener behind.
struct Serve {
    child: Child,
    addr: String,
    early: String,
    rest_rx: mpsc::Receiver<String>,
}

fn spawn_serve(extra: &[&str]) -> Serve {
    let mut child = srsp_bin()
        .args(["serve", "--listen", "127.0.0.1:0"])
        .args(extra)
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn serve");
    let mut reader = BufReader::new(child.stderr.take().expect("serve stderr piped"));
    let mut early = String::new();
    let addr = loop {
        let mut line = String::new();
        let n = reader.read_line(&mut line).expect("read serve stderr");
        assert!(n > 0, "serve exited before announcing its address:\n{early}");
        early.push_str(&line);
        if let Some(a) = line.trim_end().strip_prefix("serve: listening on ") {
            break a.to_string();
        }
    };
    let (tx, rest_rx) = mpsc::channel();
    std::thread::spawn(move || {
        let mut rest = String::new();
        let _ = reader.read_to_string(&mut rest);
        let _ = tx.send(rest);
    });
    Serve {
        child,
        addr,
        early,
        rest_rx,
    }
}

impl Serve {
    /// Wait for the drain exit and return the full stderr transcript.
    fn finish(mut self) -> String {
        let status = self.child.wait().expect("wait serve");
        let rest = self.rest_rx.recv().unwrap_or_default();
        let all = format!("{}{rest}", self.early);
        assert!(status.success(), "serve exited with {status}:\n{all}");
        all
    }
}

impl Drop for Serve {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

fn spawn_work(addr: &str, extra: &[&str]) -> Child {
    srsp_bin()
        .args(["work", "--connect", addr])
        .args(extra)
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn work")
}

/// Library level: the wire envelopes carry the pipeline artifacts
/// losslessly — a plan or partial that crosses a frame decodes equal.
#[test]
fn wire_envelopes_carry_pipeline_artifacts_losslessly() {
    let lowered = ExecutionPlan::lower_sweep(&tiny_runner(), &ratio_plan());
    let env = Envelope::Request {
        plan: lowered.clone(),
    };
    match Envelope::from_json(&env.to_json()).unwrap() {
        Envelope::Request { plan } => assert_eq!(plan, lowered),
        other => panic!("decoded {other:?}"),
    }
    let spec = shard::partition(&lowered, 2).remove(0);
    let partial = PartialReport::from_shard(&spec, &execute_shard(&spec));
    let env = Envelope::Ack {
        job: 7,
        batch: 9,
        partial: partial.clone(),
    };
    match Envelope::from_json(&env.to_json()).unwrap() {
        Envelope::Ack {
            job,
            batch,
            partial: p,
        } => {
            assert_eq!((job, batch), (7, 9));
            assert_eq!(p.to_json(), partial.to_json(), "ack must stay lossless");
        }
        other => panic!("decoded {other:?}"),
    }
}

/// Library level: the coordinator's final-assembly helper — a complete
/// grid wrapped by `from_grid` merges byte-identical to the in-process
/// report.
#[test]
fn from_grid_partial_merges_byte_identical() {
    let runner = tiny_runner();
    let plan = ratio_plan();
    let local = Report::from_cells(&runner.run_sweep(&plan));
    let lowered = ExecutionPlan::lower_sweep(&runner, &plan);
    let spec = shard::partition(&lowered, 1).remove(0);
    let p = PartialReport::from_shard(&spec, &execute_shard(&spec));
    let grid = PartialReport::from_grid(p.rows, CacheCounters::default());
    let merged = Report::merge(&[grid]).unwrap();
    assert_eq!(merged.to_csv(), local.to_csv());
    assert_eq!(merged.to_json(), local.to_json());
}

/// The tentpole acceptance gate: a sweep submitted through a coordinator
/// with one worker emits a report byte-identical to `--jobs 1`, the
/// coordinator drains after `--max-jobs`, and the worker exits cleanly.
#[test]
fn served_sweep_byte_identical_to_local_and_drains() {
    let dir = scratch("identity");
    let local = local_reference(&dir);
    let serve = spawn_serve(&["--max-jobs", "1"]);
    let mut worker = spawn_work(&serve.addr, &[]);
    let served = dir.join("served.csv");
    let out = srsp_bin()
        .args(["submit", "--connect", &serve.addr])
        .args(SWEEP_FLAGS)
        .args(["--report", "csv", "--out", served.to_str().unwrap()])
        .output()
        .expect("spawn submit");
    assert!(
        out.status.success(),
        "submit failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert_eq!(
        std::fs::read(&served).unwrap(),
        local,
        "served report must be byte-identical to --jobs 1"
    );
    let stderr = serve.finish();
    assert!(
        stderr.contains("drained after 1 job(s)"),
        "drain summary missing:\n{stderr}"
    );
    let ws = worker.wait().expect("wait worker");
    assert!(ws.success(), "worker must exit cleanly on drain");
}

/// Fault tolerance: the only connected worker dies mid-shard (after
/// simulating its first batch, before acking). The coordinator
/// re-dispatches to a later-joining healthy worker and the job still
/// completes byte-identical — no gap, no stale ack.
#[test]
fn worker_killed_mid_shard_completes_via_retry() {
    let dir = scratch("retry");
    let local = local_reference(&dir);
    let serve = spawn_serve(&["--max-jobs", "1", "--shard-cells", "2"]);
    // The doomed worker connects alone, so it is guaranteed the first
    // dispatch; --die-after 0 kills it before its first ack.
    let mut doomed = spawn_work(&serve.addr, &["--die-after", "0"]);
    let served = dir.join("served.csv");
    let mut submit = srsp_bin()
        .args(["submit", "--connect", &serve.addr])
        .args(SWEEP_FLAGS)
        .args(["--report", "csv", "--out", served.to_str().unwrap()])
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn submit");
    let doomed_status = doomed.wait().expect("wait doomed worker");
    assert_eq!(
        doomed_status.code(),
        Some(3),
        "the doomed worker must die mid-shard, not exit cleanly"
    );
    // Only now does a healthy worker join: every batch it executes is a
    // re-dispatch or a never-dispatched remainder.
    let mut healthy = spawn_work(&serve.addr, &[]);
    let ss = submit.wait().expect("wait submit");
    assert!(ss.success(), "submit must survive the worker death");
    assert_eq!(
        std::fs::read(&served).unwrap(),
        local,
        "retried report must be byte-identical to --jobs 1"
    );
    let stderr = serve.finish();
    assert!(
        stderr.contains("re-dispatching"),
        "the retry must be visible in the coordinator log:\n{stderr}"
    );
    let hs = healthy.wait().expect("wait healthy worker");
    assert!(hs.success());
}

/// The cache leg: with `--cache` on the coordinator, a resubmit of the
/// same sweep is answered entirely from warm cells — zero batches
/// dispatched — and both reports are byte-identical to the local run.
#[test]
fn warm_cache_resubmit_dispatches_zero_batches() {
    let dir = scratch("warm");
    let local = local_reference(&dir);
    let cache = dir.join("cache");
    let serve = spawn_serve(&["--max-jobs", "2", "--cache", cache.to_str().unwrap()]);
    let mut worker = spawn_work(&serve.addr, &[]);
    let submit = |out: &PathBuf| {
        let o = srsp_bin()
            .args(["submit", "--connect", &serve.addr])
            .args(SWEEP_FLAGS)
            .args(["--report", "csv", "--out", out.to_str().unwrap()])
            .output()
            .expect("spawn submit");
        assert!(
            o.status.success(),
            "submit failed:\n{}",
            String::from_utf8_lossy(&o.stderr)
        );
        String::from_utf8_lossy(&o.stderr).to_string()
    };
    let (cold_out, warm_out) = (dir.join("cold.csv"), dir.join("warm.csv"));
    let cold_stderr = submit(&cold_out);
    assert!(
        !cold_stderr.contains(", 0 dispatched)"),
        "the cold submit must dispatch batches:\n{cold_stderr}"
    );
    let warm_stderr = submit(&warm_out);
    assert!(
        warm_stderr.contains(", 0 dispatched)"),
        "the warm resubmit must dispatch nothing:\n{warm_stderr}"
    );
    assert_eq!(std::fs::read(&cold_out).unwrap(), local, "cold serve vs local");
    assert_eq!(std::fs::read(&warm_out).unwrap(), local, "warm serve vs local");
    serve.finish();
    let ws = worker.wait().expect("wait worker");
    assert!(ws.success());
}

/// Protocol hygiene over a raw socket: a frame from a different wire
/// generation, a non-JSON line, and an unknown hello role are each
/// answered with a loud error envelope, never misread.
#[test]
fn wire_version_mismatch_and_malformed_frames_rejected_loudly() {
    // No --max-jobs: this coordinator never drains; Drop kills it.
    let serve = spawn_serve(&[]);
    let probe = |frame: &str| -> String {
        let mut s = TcpStream::connect(&serve.addr).expect("connect raw");
        s.write_all(frame.as_bytes()).expect("write raw frame");
        let mut line = String::new();
        BufReader::new(s).read_line(&mut line).expect("read reply");
        line
    };
    let reply = probe("{\"wire_version\":999,\"kind\":\"hello\",\"role\":\"work\"}\n");
    assert!(reply.contains("\"kind\":\"error\""), "{reply}");
    assert!(reply.contains("wire version"), "{reply}");
    let reply = probe("this is not a frame\n");
    assert!(reply.contains("\"kind\":\"error\""), "{reply}");
    assert!(reply.contains("malformed wire frame"), "{reply}");
    let reply = probe("{\"wire_version\":1,\"kind\":\"hello\",\"role\":\"warble\"}\n");
    assert!(reply.contains("unknown hello role"), "{reply}");
}

/// The service flags are scoped to their commands through the
/// declarative registry, and each service command names its required
/// flag.
#[test]
fn cli_rejects_misplaced_service_flags() {
    for (args, needle) in [
        (vec!["run", "--listen", "x"], "--listen applies to"),
        (vec!["sweep", "--connect", "x"], "--connect applies to"),
        (vec!["serve", "--die-after", "0"], "--die-after applies to"),
        (vec!["work", "--listen", "x"], "--listen applies to"),
        (vec!["submit", "--deadline", "5"], "--deadline applies to"),
        (vec!["run", "--retries", "1"], "--retries applies to"),
        (vec!["run", "--max-jobs", "1"], "--max-jobs applies to"),
        (vec!["submit", "--shard-cells", "4"], "--shard-cells applies to"),
        (vec!["serve"], "needs --listen"),
        (vec!["work"], "needs --connect"),
        (vec!["submit"], "needs --connect"),
        (
            vec!["submit", "--connect", "x", "--jobs", "2"],
            "--jobs does not apply",
        ),
        (
            vec!["serve", "--listen", "x", "--trace", "t"],
            "--trace applies to",
        ),
        (vec!["serve", "--listen", "x", "--deadline", "0"], "at least 1"),
        (vec!["submit", "--connect", "x"], "registry-axis sweep"),
    ] {
        let out = srsp_bin().args(&args).output().expect("spawn srsp");
        assert!(!out.status.success(), "{args:?} must be rejected");
        let stderr = String::from_utf8_lossy(&out.stderr);
        assert!(
            stderr.contains(needle),
            "{args:?}: expected '{needle}' in:\n{stderr}"
        );
    }
}
