//! Paper-scale shape test: the qualitative claims of §5.2 must hold on
//! the Table-1 device (64 CUs, paper-class inputs):
//!
//! * Fig. 4 — Scope-only and sRSP are the winners; sRSP clearly beats the
//!   Baseline; naive RSP loses (most of) its gains; Steal-only is no
//!   better than Baseline for PRK/SSSP.
//! * Fig. 5 — Scope-only reduces L2 traffic below Baseline; sRSP's L2
//!   traffic is below naive RSP's.
//! * Fig. 6 — sRSP's synchronization overhead is below naive RSP's.
//! * Scalability — naive RSP degrades as CU count grows; sRSP does not.
//!
//! This is the slowest test in the suite (a full 15-run matrix); it runs
//! the Paper-size inputs so the effects the paper reports actually have
//! room to appear.

use srsp::config::{DeviceConfig, Scenario};
use srsp::harness::figures::{fig4_speedup, fig5_l2, fig6_overhead, run_matrix};
use srsp::harness::presets::WorkloadSize;

#[test]
fn paper_shape_64_cus() {
    let cfg = DeviceConfig::default();
    let results = run_matrix(&cfg, WorkloadSize::Paper);

    let f4 = fig4_speedup(&results);
    let f5 = fig5_l2(&results);
    let f6 = fig6_overhead(&results);
    eprintln!("{}", f4.render());
    eprintln!("{}", f5.render());
    eprintln!("{}", f6.render());

    let (srsp, rsp) = (Scenario::SRSP, Scenario::RSP);
    // Fig. 4 claims.
    assert!(f4.geomean(srsp) > 1.15, "sRSP must clearly beat Baseline");
    assert!(
        f4.geomean(srsp) > f4.geomean(rsp) + 0.1,
        "sRSP must clearly beat naive RSP (got {:.3} vs {:.3})",
        f4.geomean(srsp),
        f4.geomean(rsp)
    );
    assert!(f4.geomean(Scenario::SCOPE_ONLY) > 1.2, "local scope is a big win");
    assert!(
        f4.geomean(Scenario::STEAL_ONLY) < 1.1,
        "global-scope stealing alone must not pay (paper: PRK/SSSP)"
    );
    for app in ["PRK", "SSSP", "MIS"] {
        assert!(
            f4.value(app, srsp).unwrap() > f4.value(app, rsp).unwrap() * 0.97,
            "{app}: sRSP must not lose to naive RSP"
        );
    }

    // Fig. 5 claims.
    assert!(f5.geomean(Scenario::SCOPE_ONLY) < 0.9);
    assert!(f5.geomean(srsp) < f5.geomean(rsp));

    // Fig. 6 claim.
    assert!(
        f6.geomean(srsp) < 0.95,
        "selective promotion must be cheaper than naive (got {:.3})",
        f6.geomean(srsp)
    );
}

#[test]
fn rsp_degrades_with_scale_srsp_does_not() {
    // Small sweep (8 vs 64 CUs) of the steal-heavy scenarios.
    let speedups = |cus: u32| {
        let cfg = DeviceConfig {
            num_cus: cus,
            ..DeviceConfig::default()
        };
        let results = run_matrix(&cfg, WorkloadSize::Paper);
        let f4 = fig4_speedup(&results);
        (f4.geomean(Scenario::RSP), f4.geomean(Scenario::SRSP))
    };
    let (rsp_small, _srsp_small) = speedups(8);
    let (rsp_big, srsp_big) = speedups(64);
    assert!(
        rsp_big < rsp_small - 0.1,
        "naive RSP must degrade with CU count ({rsp_small:.3} -> {rsp_big:.3})"
    );
    assert!(
        srsp_big > rsp_big + 0.2,
        "sRSP must stay ahead at scale ({srsp_big:.3} vs {rsp_big:.3})"
    );
}
