//! Observational-equivalence property (DESIGN.md §6): on randomized
//! asymmetric-sharing programs, the sRSP implementation, the naive RSP
//! implementation and an all-global-scope reference must produce
//! *identical final memory*. Timing may differ; semantics may not.
//!
//! Programs follow the paper's sharing idiom: L locks, each guarding a
//! disjoint set of counter cells; the lock's owner work-group uses cheap
//! local synchronization (promoted remotely under RSP/sRSP, global under
//! the reference), other work-groups occasionally intrude. All updates
//! are commutative fetch-style adds, so the final state is independent of
//! the acquisition order — any deviation means lost updates or broken
//! mutual exclusion.

use srsp::config::{DeviceConfig, Protocol};
use srsp::gpu::Device;
use srsp::kir::{Asm, Program, Src};
use srsp::proptest::{run_prop, Gen};
use srsp::sync::{AtomicOp, MemOrder, Scope};

const LOCKS: u64 = 0x1000;
const CELLS: u64 = 0x8000;
const NUM_WGS: u32 = 4;

#[derive(Debug, Clone)]
struct Cs {
    lock: u32,
    /// (cell index within the lock's set, increment)
    updates: Vec<(u32, u32)>,
}

#[derive(Debug, Clone)]
struct Spec {
    num_locks: u32,
    cells_per_lock: u32,
    /// Per-wg sequence of critical sections.
    programs: Vec<Vec<Cs>>,
}

fn gen_spec(g: &mut Gen) -> Spec {
    // One lock per work-group, owned by that work-group: the RSP contract
    // (and the paper's asymmetric-sharing model) requires a *unique*
    // local sharer per sync variable -- two owners on different CUs doing
    // wg-scope synchronization on one lock would be a racy program.
    let num_locks = NUM_WGS;
    let cells_per_lock = g.u32(1..4);
    let programs = (0..NUM_WGS)
        .map(|wg| {
            let n_cs = g.len(1..8);
            (0..n_cs)
                .map(|_| {
                    // A wg mostly uses its own lock (asymmetric sharing),
                    // occasionally intrudes on someone else's.
                    let lock = if g.chance(0.75) { wg } else { g.u32(0..num_locks) };
                    let n_upd = g.len(1..4);
                    let updates = (0..n_upd)
                        .map(|_| (g.u32(0..cells_per_lock), g.u32(1..100)))
                        .collect();
                    Cs { lock, updates }
                })
                .collect()
        })
        .collect();
    Spec {
        num_locks,
        cells_per_lock,
        programs,
    }
}

fn cell_addr(spec: &Spec, lock: u32, cell: u32) -> u64 {
    CELLS + (lock * spec.cells_per_lock + cell) as u64 * 64 // line-isolated
}

/// Emit the whole straight-line program for one wg under a sync flavor.
/// `owner_local`: lock owners use wg scope (RSP protocols); intruders use
/// remote ops. Otherwise everything is cmp scope (reference).
fn build(spec: &Spec, owner_local: bool) -> Program {
    let mut a = Asm::new();
    let wg = a.reg();
    let lock = a.reg();
    let cell = a.reg();
    let old = a.reg();
    let tmp = a.reg();

    a.wg_id(wg);
    // Dispatch on wg id.
    for w in 0..NUM_WGS {
        a.eq(tmp, wg, Src::I(w as u64));
        a.bnz(tmp, &format!("wg{w}"));
    }
    a.halt();

    for (w, css) in spec.programs.iter().enumerate() {
        a.label(&format!("wg{w}"));
        for (k, cs) in css.iter().enumerate() {
            let owner = w as u32 == cs.lock;
            let tag = format!("w{w}c{k}");
            a.imm(lock, LOCKS + cs.lock as u64 * 64);
            a.label(&format!("spin_{tag}"));
            let (acq, acq_ord) = (AtomicOp::Cas, MemOrder::Acquire);
            if owner_local && owner {
                a.atomic(old, acq, lock, Src::I(1), Src::I(0), acq_ord, Scope::Wg);
            } else if owner_local {
                a.remote_atomic(old, acq, lock, Src::I(1), Src::I(0), acq_ord);
            } else {
                a.atomic(old, acq, lock, Src::I(1), Src::I(0), acq_ord, Scope::Cmp);
            }
            a.bnz(old, &format!("spin_{tag}"));
            for &(c, inc) in &cs.updates {
                a.imm(cell, cell_addr(spec, cs.lock, c));
                a.ld(tmp, cell, 0, 4);
                a.add(tmp, tmp, Src::I(inc as u64));
                a.st(cell, 0, tmp, 4);
            }
            let (rel, rel_ord) = (AtomicOp::Store, MemOrder::Release);
            if owner_local && owner {
                a.atomic(old, rel, lock, Src::I(0), Src::I(0), rel_ord, Scope::Wg);
            } else if owner_local {
                a.remote_atomic(old, rel, lock, Src::I(0), Src::I(0), rel_ord);
            } else {
                a.atomic(old, rel, lock, Src::I(0), Src::I(0), rel_ord, Scope::Cmp);
            }
        }
        a.halt();
    }
    a.finish()
}

/// Expected final cell values (order-independent sums).
fn expectation(spec: &Spec) -> Vec<(u64, u32)> {
    let mut sums = vec![0u32; (spec.num_locks * spec.cells_per_lock) as usize];
    for css in &spec.programs {
        for cs in css {
            for &(c, inc) in &cs.updates {
                sums[(cs.lock * spec.cells_per_lock + c) as usize] += inc;
            }
        }
    }
    sums.iter()
        .enumerate()
        .map(|(i, &v)| {
            let lock = i as u32 / spec.cells_per_lock;
            let cell = i as u32 % spec.cells_per_lock;
            (cell_addr_raw(spec, lock, cell), v)
        })
        .collect()
}

fn cell_addr_raw(spec: &Spec, lock: u32, cell: u32) -> u64 {
    CELLS + (lock * spec.cells_per_lock + cell) as u64 * 64
}

fn run(spec: &Spec, protocol: Protocol, owner_local: bool) -> Vec<u32> {
    let mut dev = Device::new(DeviceConfig::small(), protocol);
    dev.launch_simple(&build(spec, owner_local), NUM_WGS);
    expectation(spec)
        .iter()
        .map(|&(addr, _)| dev.mem.backing.read_u32(addr))
        .collect()
}

#[test]
fn srsp_equals_naive_equals_global_reference() {
    run_prop("protocol_equivalence", 40, |g| {
        let spec = gen_spec(g);
        let want: Vec<u32> = expectation(&spec).iter().map(|&(_, v)| v).collect();
        let reference = run(&spec, Protocol::SCOPED_ONLY, false);
        let naive = run(&spec, Protocol::RSP_NAIVE, true);
        let srsp = run(&spec, Protocol::SRSP, true);
        assert_eq!(reference, want, "global-scope reference lost updates");
        assert_eq!(naive, want, "naive RSP diverged from expectation");
        assert_eq!(srsp, want, "sRSP diverged from expectation");
    });
}

#[test]
fn srsp_deterministic_for_seed() {
    run_prop("srsp_determinism", 10, |g| {
        let spec = gen_spec(g);
        let a = run(&spec, Protocol::SRSP, true);
        let b = run(&spec, Protocol::SRSP, true);
        assert_eq!(a, b, "same program must replay identically");
    });
}

#[test]
fn invariants_hold_after_random_programs() {
    run_prop("post_run_invariants", 15, |g| {
        let spec = gen_spec(g);
        let mut dev = Device::new(DeviceConfig::small(), Protocol::SRSP);
        dev.launch_simple(&build(&spec, true), NUM_WGS);
        dev.mem.check_invariants();
    });
}
