//! Workload-level coverage of the LR-TBL/PA-TBL overflow paths: force
//! tiny (or zero) table capacities through the device config and assert
//! that (a) the overflow counters fire and (b) sRSP still passes the
//! workloads' native oracles — the same oracles the ScopedOnly-protocol
//! scenarios validate against, so the degraded-table machinery is
//! checked for correctness, not just liveness. (The sFIFO/table
//! *performance* sensitivity is the `ablations` bench; this is the
//! correctness side.)

use srsp::config::{DeviceConfig, Scenario};
use srsp::harness::presets::{WorkloadPreset, WorkloadSize};
use srsp::harness::runner::run_validated;
use srsp::workload::registry;

fn tiny_cfg(lr: u32, pa: u32) -> DeviceConfig {
    DeviceConfig {
        lr_tbl_entries: lr,
        pa_tbl_entries: pa,
        ..DeviceConfig::small()
    }
}

fn stress_preset(r: f64) -> WorkloadPreset {
    WorkloadPreset::with_params(
        registry::STRESS,
        WorkloadSize::Tiny,
        3,
        &[("remote_ratio".into(), r)],
    )
    .unwrap()
}

#[test]
fn disabled_lr_tbl_degrades_to_full_drains_but_stays_exact() {
    // lr_tbl_entries = 0: every wg-scope release overflows (sticky), so
    // every selective flush degenerates to a conservative full drain and
    // requester-side lookups must not short-circuit the broadcast.
    let cfg = tiny_cfg(0, 16);
    let stress = stress_preset(0.5);
    let (run, ok) = run_validated(&cfg, &stress, Scenario::SRSP);
    assert!(ok, "stress must stay exact with a disabled LR-TBL");
    assert!(
        run.stats.lr_tbl_overflows > 0,
        "capacity 0 must overflow on every record"
    );
    // The ScopedOnly protocol validates against the identical oracle.
    let (_, ok) = run_validated(&cfg, &stress, Scenario::STEAL_ONLY);
    assert!(ok);

    let sssp = WorkloadPreset::new_seeded(registry::SSSP, WorkloadSize::Tiny, 3);
    let (run, ok) = run_validated(&cfg, &sssp, Scenario::SRSP);
    assert!(ok, "SSSP must stay exact with a disabled LR-TBL");
    assert!(run.stats.lr_tbl_overflows > 0);
}

#[test]
fn one_entry_tables_overflow_on_prodcons_and_stay_exact() {
    // The producer–consumer kernel releases one flag per slot — dozens
    // of distinct sync addresses per producer CU — so one-entry tables
    // thrash: LR-TBL displacement on the producer side, PA-TBL eager
    // invalidates on the consumer-armed side.
    let cfg = tiny_cfg(1, 1);
    let preset = WorkloadPreset::new_seeded(registry::PRODCONS, WorkloadSize::Tiny, 5);
    let (run, ok) = run_validated(&cfg, &preset, Scenario::SRSP);
    assert!(ok, "prodcons must stay exact with one-entry tables");
    assert!(
        run.stats.lr_tbl_overflows > 0,
        "per-slot flag releases must displace a one-entry LR-TBL"
    );
    assert!(
        run.stats.pa_tbl_overflows > 0,
        "per-slot flag arming must overflow a one-entry PA-TBL"
    );
    // Same input under the ScopedOnly protocol: identical oracle.
    let (_, ok) = run_validated(&cfg, &preset, Scenario::STEAL_ONLY);
    assert!(ok);
}

#[test]
fn one_entry_tables_keep_the_graph_apps_exact() {
    let cfg = tiny_cfg(1, 1);
    for id in [registry::SSSP, registry::MIS, registry::BFS] {
        let preset = WorkloadPreset::new_seeded(id, WorkloadSize::Tiny, 9);
        for scenario in [Scenario::STEAL_ONLY, Scenario::RSP, Scenario::SRSP] {
            let (_, ok) = run_validated(&cfg, &preset, scenario);
            assert!(ok, "{id}/{scenario:?} with one-entry tables");
        }
    }
}
