//! Extended coverage: system scope, multiple work-groups per CU,
//! high-degree (multi-row) tile splitting, config-file round trips and
//! host-driver edge cases.

use srsp::config::{parse_config_str, DeviceConfig, Protocol, Scenario};
use srsp::gpu::Device;
use srsp::kir::{Asm, Src};
use srsp::mem::{BackingStore, MemAlloc};
use srsp::sync::{AtomicOp, MemOrder, Scope};
use srsp::workload::driver::run_scenario_seeded;
use srsp::workload::engine::NativeMath;
use srsp::workload::graph::Graph;
use srsp::workload::mis::Mis;
use srsp::workload::pagerank::PageRank;
use srsp::workload::sssp::Sssp;

// ---------------------------------------------------------------------
// System scope
// ---------------------------------------------------------------------

#[test]
fn sys_scope_publishes_through_l2_to_backing() {
    let mut dev = Device::new(DeviceConfig::small(), Protocol::SRSP);
    let t = dev.mem.l1_write(0, 0x4000, 4, 77, 0);
    // sys-scope release: L1 flushed, then L2 flushed to the backing store.
    let out = srsp::sync::engine::sync_op(
        &mut dev.mem, Protocol::SRSP, 0, 0x4040, AtomicOp::Store,
        MemOrder::Release, Scope::Sys, 1, 0, t,
    );
    assert_eq!(
        dev.mem.backing.read_u32(0x4000),
        77,
        "sys release must reach the backing store"
    );
    // sys-scope acquire on another CU drops L1 *and* L2 state.
    let acq = srsp::sync::engine::sync_op(
        &mut dev.mem, Protocol::SRSP, 1, 0x4040, AtomicOp::Load,
        MemOrder::Acquire, Scope::Sys, 0, 0, out.done,
    );
    assert_eq!(acq.value, 1);
    let (v, _) = dev.mem.l1_read(1, 0x4000, 4, acq.done);
    assert_eq!(v, 77);
    dev.mem.check_invariants();
}

#[test]
fn sys_scope_message_passing_kernel() {
    // Full KIR version across protocols.
    for p in [Protocol::SCOPED_ONLY, Protocol::RSP_NAIVE, Protocol::SRSP] {
        let mut a = Asm::new();
        let wg = a.reg();
        let data = a.reg();
        let flag = a.reg();
        let v = a.reg();
        a.wg_id(wg);
        a.imm(data, 0x100);
        a.imm(flag, 0x140);
        a.bnz(wg, "reader");
        a.imm(v, 5);
        a.st(data, 0, v, 4);
        a.atomic(v, AtomicOp::Store, flag, Src::I(1), Src::I(0), MemOrder::Release, Scope::Sys);
        a.halt();
        a.label("reader");
        a.label("spin");
        a.atomic(v, AtomicOp::Load, flag, Src::I(0), Src::I(0), MemOrder::Acquire, Scope::Sys);
        a.bz(v, "spin");
        a.ld(v, data, 0, 4);
        a.st(flag, 4, v, 4);
        a.halt();
        let prog = a.finish();
        let mut dev = Device::new(DeviceConfig::small(), p);
        dev.launch_simple(&prog, 2);
        assert_eq!(dev.mem.backing.read_u32(0x144), 5, "{p:?}");
    }
}

// ---------------------------------------------------------------------
// Multiple work-groups per CU (shared L1)
// ---------------------------------------------------------------------

#[test]
fn two_wgs_per_cu_share_an_l1_for_wg_scope() {
    // 2 wgs/CU: wg0 and wg4 (on CU0) synchronize at wg scope; the
    // workloads must still validate.
    let cfg = DeviceConfig {
        num_cus: 4,
        wgs_per_cu: 2,
        ..DeviceConfig::small()
    };
    let g = Graph::small_world(128, 4, 0.2, 3);
    let oracle = PageRank::oracle(&g, 3);
    for scenario in [Scenario::SCOPE_ONLY, Scenario::SRSP] {
        let mut alloc = MemAlloc::new();
        let mut image = BackingStore::new();
        let mut prk = PageRank::setup(&g, &mut alloc, &mut image, 8, 3);
        let (run, mem) = run_scenario_seeded(&cfg, scenario, &mut prk, NativeMath, 16, image);
        assert!(run.converged);
        let got = prk.result(&mem);
        let d: f32 = got.iter().zip(&oracle).map(|(a, b)| (a - b).abs()).sum();
        assert!(d < 1e-4, "{scenario:?}: {d}");
    }
}

// ---------------------------------------------------------------------
// High-degree vertices (multi-row tiles)
// ---------------------------------------------------------------------

#[test]
fn star_graph_pagerank_exercises_row_splitting() {
    // Hub with 200 spokes: degree 200 > K_TILE=32 -> 7 tile rows whose
    // partial sums must recombine exactly.
    let n = 201u32;
    let edges: Vec<(u32, u32, u32)> = (1..n).map(|v| (0, v, 1)).collect();
    let g = Graph::from_edges(n, &edges);
    assert!(g.max_degree() > srsp::workload::engine::K_TILE as u32);
    let oracle = PageRank::oracle(&g, 5);
    let cfg = DeviceConfig::small();
    for scenario in [Scenario::BASELINE, Scenario::SRSP] {
        let mut alloc = MemAlloc::new();
        let mut image = BackingStore::new();
        let mut prk = PageRank::setup(&g, &mut alloc, &mut image, 16, 5);
        let (run, mem) = run_scenario_seeded(&cfg, scenario, &mut prk, NativeMath, 16, image);
        assert!(run.converged);
        let got = prk.result(&mem);
        let d: f32 = got.iter().zip(&oracle).map(|(a, b)| (a - b).abs()).sum();
        assert!(d < 1e-4, "{scenario:?}: hub splitting broke ranks ({d})");
    }
}

#[test]
fn star_graph_sssp_and_mis_with_hub() {
    let n = 100u32;
    let edges: Vec<(u32, u32, u32)> = (1..n).map(|v| (0, v, v)).collect();
    let g = Graph::from_edges(n, &edges);
    let cfg = DeviceConfig::small();

    let oracle = Sssp::oracle(&g, 0);
    let mut alloc = MemAlloc::new();
    let mut image = BackingStore::new();
    let mut sssp = Sssp::setup(&g, &mut alloc, &mut image, 8, 0);
    let (run, mem) = run_scenario_seeded(&cfg, Scenario::SRSP, &mut sssp, NativeMath, 100, image);
    assert!(run.converged);
    assert_eq!(sssp.result(&mem), oracle);

    let mut alloc = MemAlloc::new();
    let mut image = BackingStore::new();
    let mut mis = Mis::setup(&g, &mut alloc, &mut image, 8);
    let (run, mem) = run_scenario_seeded(&cfg, Scenario::SRSP, &mut mis, NativeMath, 64, image);
    assert!(run.converged);
    let state = mis.result(&mem);
    Mis::validate_mis(&g, &state).unwrap();
    assert_eq!(state, Mis::oracle(&g));
}

// ---------------------------------------------------------------------
// Config files
// ---------------------------------------------------------------------

#[test]
fn config_file_round_trip_through_disk() {
    let dir = std::env::temp_dir().join("srsp_cfg_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("dev.cfg");
    std::fs::write(
        &path,
        "# experiment config\nnum_cus = 16\nl1_size = 8k\nl1_ways = 8\nlr_tbl_entries = 4\n",
    )
    .unwrap();
    let cfg = srsp::config::file::load_config(&path).unwrap();
    assert_eq!(cfg.num_cus, 16);
    assert_eq!(cfg.l1_size, 8 * 1024);
    assert_eq!(cfg.l1_sets(), 16); // 8k/64/8
    assert_eq!(cfg.lr_tbl_entries, 4);
    cfg.validate().unwrap();
}

#[test]
fn custom_config_device_runs_workload() {
    let cfg = parse_config_str(
        "num_cus = 8\nl1_size = 4k\nl2_size = 64k\nl1_sfifo = 8\nlr_tbl_entries = 8\npa_tbl_entries = 8\n",
    )
    .unwrap();
    let g = Graph::road_grid(8, 8, 1);
    let oracle = Sssp::oracle(&g, 0);
    let mut alloc = MemAlloc::new();
    let mut image = BackingStore::new();
    let mut sssp = Sssp::setup(&g, &mut alloc, &mut image, 4, 0);
    let (run, mem) = run_scenario_seeded(&cfg, Scenario::SRSP, &mut sssp, NativeMath, 200, image);
    assert!(run.converged);
    assert_eq!(sssp.result(&mem), oracle);
}

// ---------------------------------------------------------------------
// Driver edges
// ---------------------------------------------------------------------

#[test]
fn empty_workload_rounds_converge_immediately() {
    // A graph with one isolated vertex: MIS decides it in one round.
    let g = Graph::from_edges(2, &[(0, 1, 1)]);
    let cfg = DeviceConfig::small();
    let mut alloc = MemAlloc::new();
    let mut image = BackingStore::new();
    let mut mis = Mis::setup(&g, &mut alloc, &mut image, 2);
    let (run, mem) = run_scenario_seeded(&cfg, Scenario::SRSP, &mut mis, NativeMath, 8, image);
    assert!(run.converged);
    assert!(run.rounds <= 2);
    Mis::validate_mis(&g, &mis.result(&mem)).unwrap();
}

#[test]
fn single_cu_device_all_scenarios() {
    // Degenerate device: 1 CU. Steal scans have no victims; everything
    // must still converge and validate.
    let cfg = DeviceConfig {
        num_cus: 1,
        ..DeviceConfig::small()
    };
    let g = Graph::small_world(64, 4, 0.2, 5);
    let oracle = PageRank::oracle(&g, 2);
    for scenario in Scenario::ALL {
        let mut alloc = MemAlloc::new();
        let mut image = BackingStore::new();
        let mut prk = PageRank::setup(&g, &mut alloc, &mut image, 8, 2);
        let (run, mem) = run_scenario_seeded(&cfg, scenario, &mut prk, NativeMath, 8, image);
        assert!(run.converged, "{scenario:?}");
        let got = prk.result(&mem);
        let d: f32 = got.iter().zip(&oracle).map(|(a, b)| (a - b).abs()).sum();
        assert!(d < 1e-4, "{scenario:?}: {d}");
    }
}

#[test]
fn stats_steal_counters_consistent() {
    // tasks_executed == total tasks; steals <= attempts; successes +
    // failures <= attempts (attempts include cheap pre-check skips).
    let cfg = DeviceConfig {
        num_cus: 4,
        ..DeviceConfig::small()
    };
    let g = Graph::power_law(256, 2, 7);
    let mut alloc = MemAlloc::new();
    let mut image = BackingStore::new();
    let mut mis = Mis::setup(&g, &mut alloc, &mut image, 8);
    let (run, _mem) = run_scenario_seeded(&cfg, Scenario::SRSP, &mut mis, NativeMath, 64, image);
    let s = &run.stats;
    assert!(s.tasks_stolen <= s.steal_attempts);
    assert!(s.tasks_stolen + s.steal_failures <= s.steal_attempts + 1);
    assert!(s.tasks_executed > 0);
    assert_eq!(
        s.tasks_executed, s.compute_ops,
        "every claimed task executes exactly one compute op"
    );
}

// ---------------------------------------------------------------------
// Bundled real-format input
// ---------------------------------------------------------------------

#[test]
fn bundled_dimacs_sample_runs_end_to_end() {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("data/sample_road.gr");
    let text = std::fs::read_to_string(path).expect("bundled sample present");
    let g = Graph::from_dimacs_gr(&text).unwrap();
    g.validate().unwrap();
    assert_eq!(g.n, 16);
    let oracle = Sssp::oracle(&g, 0);
    let cfg = DeviceConfig::small();
    for scenario in [Scenario::BASELINE, Scenario::SRSP, Scenario::HLRC] {
        let mut alloc = MemAlloc::new();
        let mut image = BackingStore::new();
        let mut sssp = Sssp::setup(&g, &mut alloc, &mut image, 4, 0);
        let (run, mem) = run_scenario_seeded(&cfg, scenario, &mut sssp, NativeMath, 200, image);
        assert!(run.converged, "{scenario:?}");
        assert_eq!(sssp.result(&mem), oracle, "{scenario:?}");
    }
}
