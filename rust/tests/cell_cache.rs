//! The content-addressed result cache end to end: a sweep run against a
//! `--cache` directory stores every oracle-validated cell row, and a
//! repeat of the same sweep simulates **zero** cells while emitting a
//! report byte-identical to the cold run — for any `--jobs` count and
//! across the `--workers` subprocess boundary. The cache is invisible in
//! results by construction (cached rows ARE the rows the cold run
//! emitted), so these tests pin the observable contract: byte-identity,
//! hit/miss accounting, key sensitivity to every input that matters,
//! loud skipping of corrupt store lines, and the `srsp cache`
//! maintenance surface.

use std::path::PathBuf;
use std::process::Command;

use srsp::config::DeviceConfig;
use srsp::coordinator::{axis, shard, ExecutionPlan, Runner, Seeding, SweepPlan};
use srsp::harness::presets::WorkloadSize;
use srsp::harness::report::{PartialReport, Report};
use srsp::harness::runner::execute_shard;
use srsp::workload::registry;

fn srsp_bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_srsp"))
}

/// A scratch directory unique to this test process + test name.
fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("srsp-test-{}-{name}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("create scratch dir");
    dir
}

/// Run `srsp` expecting success; returns (stdout, stderr).
fn run_ok(args: &[&str]) -> (String, String) {
    let out = srsp_bin().args(args).output().expect("spawn srsp");
    assert!(
        out.status.success(),
        "{args:?} failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    (
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
    )
}

/// The base 6-cell sweep every cache test reuses (2 remote-ratio points
/// × 3 protocol scenarios, oracle-gated at tiny scale).
fn sweep_args(store: &str, out: &str) -> Vec<String> {
    [
        "sweep",
        "--axis",
        "remote-ratio",
        "--app",
        "stress",
        "--size",
        "tiny",
        "--seed",
        "11",
        "--points",
        "remote-ratio=0,0.5",
        "--cus",
        "4",
        "--report",
        "csv",
        "--out",
        out,
        "--cache",
        store,
    ]
    .iter()
    .map(|s| s.to_string())
    .collect()
}

fn run_sweep(store: &str, out: &PathBuf, extra: &[&str]) -> String {
    let mut args = sweep_args(store, out.to_str().unwrap());
    args.extend(extra.iter().map(|s| s.to_string()));
    let argv: Vec<&str> = args.iter().map(|s| s.as_str()).collect();
    run_ok(&argv).1
}

/// The acceptance gate: a warm sweep — same flags, any `--jobs` count,
/// even across the `--workers` subprocess boundary — simulates zero
/// cells and emits a report byte-identical to the cold run.
#[test]
fn warm_sweeps_are_byte_identical_and_simulate_nothing() {
    let dir = scratch("cold-warm");
    let store = dir.join("store");
    let store = store.to_str().unwrap();
    let (cold, warm_j4, warm_w2) = (dir.join("cold.csv"), dir.join("j4.csv"), dir.join("w2.csv"));

    let err = run_sweep(store, &cold, &["--jobs", "2"]);
    assert!(err.contains("cache: hits=0 misses=6"), "cold run:\n{err}");

    let err = run_sweep(store, &warm_j4, &["--jobs", "4"]);
    assert!(err.contains("cache: hits=6 misses=0"), "warm --jobs 4:\n{err}");

    let err = run_sweep(store, &warm_w2, &["--workers", "2"]);
    assert!(err.contains("cache: hits=6 misses=0"), "warm --workers 2:\n{err}");

    let cold = std::fs::read(&cold).unwrap();
    assert!(!cold.is_empty());
    assert_eq!(std::fs::read(&warm_j4).unwrap(), cold, "--jobs 4 warm run");
    assert_eq!(std::fs::read(&warm_w2).unwrap(), cold, "--workers 2 warm run");

    // The maintenance view agrees: the last recorded run hit 100%.
    let (stats, _) = run_ok(&["cache", "stats", "--cache", store]);
    assert!(stats.contains("hit_rate=100.0%"), "{stats}");
    assert!(stats.contains("6 cell row(s)"), "{stats}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// The preset layer alone serves `run`: the second invocation reuses the
/// generated workload instead of rebuilding it, with identical output
/// (full Stats are not reconstructible from a report row, so `run`
/// always simulates — only generation is skipped).
#[test]
fn run_reuses_presets_across_invocations() {
    let dir = scratch("run-preset");
    let store = dir.join("store");
    let args = [
        "run",
        "--app",
        "prk",
        "--size",
        "tiny",
        "--cus",
        "4",
        "--cache",
        store.to_str().unwrap(),
    ];
    let (out1, err1) = run_ok(&args);
    assert!(err1.contains("preset_reuses=0"), "first run:\n{err1}");
    let (out2, err2) = run_ok(&args);
    assert!(err2.contains("preset_reuses=1"), "second run:\n{err2}");
    assert_eq!(out1, out2, "a reused preset must not change the run");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Key sensitivity: anything that could change a cell's result — seed,
/// device template, protocol parameters — changes its fingerprint, so a
/// perturbed sweep misses the whole store instead of serving stale rows.
#[test]
fn perturbed_sweeps_miss_the_cache() {
    let dir = scratch("perturb");
    let store = dir.join("store");
    let store = store.to_str().unwrap();
    let out = dir.join("r.csv");
    let err = run_sweep(store, &out, &[]);
    assert!(err.contains("misses=6"), "cold run:\n{err}");

    // Different base seed → different per-cell seeds → all miss (the
    // repeated --seed flag wins over the base one).
    let err = run_sweep(store, &out, &["--seed", "12"]);
    assert!(err.contains("cache: hits=0 misses=6"), "seed perturbation:\n{err}");

    // A different device template (CU count) misses.
    let err = run_sweep(store, &out, &["--cus", "2"]);
    assert!(err.contains("cache: hits=0"), "--cus perturbation:\n{err}");

    // A protocol-parameter override reaches the effective device config
    // and misses.
    let err = run_sweep(store, &out, &["--proto-param", "lr_tbl_entries=1"]);
    assert!(err.contains("cache: hits=0"), "proto-param perturbation:\n{err}");

    // And each perturbed run was itself stored: the original sweep still
    // hits 100% afterwards.
    let err = run_sweep(store, &out, &[]);
    assert!(err.contains("misses=0"), "original run after perturbations:\n{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Corrupt or foreign store lines are skipped loudly and never poison a
/// run: the intact entries still serve, and `cache stats` counts what
/// was dropped.
#[test]
fn corrupt_store_lines_are_skipped_loudly() {
    let dir = scratch("corrupt");
    let store = dir.join("store");
    let out = dir.join("r.csv");
    let err = run_sweep(store.to_str().unwrap(), &out, &[]);
    assert!(err.contains("misses=6"), "cold run:\n{err}");

    // A segment written by a broken or future tool: one non-JSON line,
    // one foreign cache version, one unknown entry kind.
    std::fs::write(
        store.join("segment-zzz.jsonl"),
        "not json at all\n{\"cache_version\":999,\"kind\":\"cell\"}\n{\"cache_version\":1,\"kind\":\"martian\"}\n",
    )
    .unwrap();

    let err = run_sweep(store.to_str().unwrap(), &out, &[]);
    assert!(err.contains("misses=0"), "intact entries must still serve:\n{err}");
    let (stats, _) = run_ok(&["cache", "stats", "--cache", store.to_str().unwrap()]);
    assert!(stats.contains("3 skipped line(s)"), "{stats}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// `--no-cache` bypasses everything: no store is opened (or created),
/// no tally is printed, and the results are the plain uncached ones.
#[test]
fn no_cache_bypasses_the_store() {
    let dir = scratch("no-cache");
    let store = dir.join("store");
    let (plain, bypassed) = (dir.join("plain.csv"), dir.join("bypassed.csv"));

    // Baseline without any cache flags.
    run_ok(&[
        "sweep", "--axis", "remote-ratio", "--app", "stress", "--size", "tiny", "--seed", "11",
        "--points", "remote-ratio=0,0.5", "--cus", "4", "--report", "csv", "--out",
        plain.to_str().unwrap(),
    ]);
    let err = run_sweep(store.to_str().unwrap(), &bypassed, &["--no-cache"]);
    assert!(!err.contains("cache:"), "--no-cache must print no tally:\n{err}");
    assert!(!store.exists(), "--no-cache must not create the store");
    assert_eq!(
        std::fs::read(&bypassed).unwrap(),
        std::fs::read(&plain).unwrap(),
        "--no-cache must match the plain run"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// The `srsp cache` maintenance surface: stats on an empty store,
/// verify on a healthy one, verify failing loudly on a tampered
/// fingerprint, and clear removing only store-owned files.
#[test]
fn cache_cli_stats_verify_clear() {
    let dir = scratch("cache-cli");
    let store = dir.join("store");
    let store_s = store.to_str().unwrap();

    // Stats on a fresh (auto-created) store.
    let (stats, _) = run_ok(&["cache", "stats", "--cache", store_s]);
    assert!(stats.contains("0 cell row(s)"), "{stats}");
    assert!(stats.contains("last run: none recorded"), "{stats}");

    let out = dir.join("r.csv");
    run_sweep(store_s, &out, &[]);
    let (verified, _) = run_ok(&["cache", "verify", "--cache", store_s]);
    assert!(!verified.trim().is_empty(), "verify must report what it checked");

    // Tamper one stored fingerprint (in a copy of the store) and verify
    // must fail naming the mismatch.
    let tampered_dir = dir.join("tampered");
    std::fs::create_dir_all(&tampered_dir).unwrap();
    let mut tampered_any = false;
    for entry in std::fs::read_dir(&store).unwrap() {
        let path = entry.unwrap().path();
        let name = path.file_name().unwrap().to_str().unwrap().to_string();
        let mut text = std::fs::read_to_string(&path).unwrap();
        if !tampered_any && name.starts_with("segment-") {
            if let Some(pos) = text.find("\"fp\":\"") {
                let i = pos + "\"fp\":\"".len();
                let old = text.as_bytes()[i];
                let new = if old == b'0' { 'f' } else { '0' };
                text.replace_range(i..i + 1, &new.to_string());
                tampered_any = true;
            }
        }
        std::fs::write(tampered_dir.join(&name), text).unwrap();
    }
    assert!(tampered_any, "expected a segment file with an fp to tamper");
    let out_cmd = srsp_bin()
        .args(["cache", "verify", "--cache", tampered_dir.to_str().unwrap()])
        .output()
        .expect("spawn srsp");
    assert!(!out_cmd.status.success(), "tampered store must fail verify");

    // Clear removes segments and runs.jsonl, leaves foreign files.
    std::fs::write(store.join("keepme.txt"), "mine").unwrap();
    run_ok(&["cache", "clear", "--cache", store_s]);
    assert!(store.join("keepme.txt").exists(), "foreign files survive clear");
    for entry in std::fs::read_dir(&store).unwrap() {
        let name = entry.unwrap().file_name().to_str().unwrap().to_string();
        assert!(
            !name.starts_with("segment-") && name != "runs.jsonl",
            "{name} should have been cleared"
        );
    }
    let (stats, _) = run_ok(&["cache", "stats", "--cache", store_s]);
    assert!(stats.contains("0 cell row(s)"), "after clear: {stats}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// The cache flags are scoped and conflicting combinations are refused
/// up front — never silently ignored.
#[test]
fn cli_rejects_misplaced_cache_flags() {
    for (args, needle) in [
        (vec!["fig4", "--cache", "x"], "--cache applies to"),
        (vec!["bench", "--cache", "x"], "--cache applies to"),
        (vec!["merge-reports", "--cache", "x"], "--cache applies to"),
        (vec!["fig5", "--no-cache"], "--no-cache applies to"),
        (
            vec!["run", "--cache", "d", "--trace", "t"],
            "--cache conflicts with --trace",
        ),
        (
            vec!["sweep", "--axis", "remote-ratio", "--cache", "d", "--trace", "t"],
            "--cache conflicts with --trace",
        ),
        (vec!["cache"], "needs --cache"),
        (vec!["cache", "bogus", "--cache", "d"], "unknown cache kind"),
        (vec!["cache", "--cache", "d", "--no-cache"], "--no-cache applies to"),
    ] {
        let out = srsp_bin().args(&args).output().expect("spawn srsp");
        assert!(!out.status.success(), "{args:?} must be rejected");
        let stderr = String::from_utf8_lossy(&out.stderr);
        assert!(stderr.contains(needle), "{args:?}: expected '{needle}' in:\n{stderr}");
    }
}

/// Satellite gate: `merge-reports` refuses a partial whose rows would
/// not round-trip losslessly (e.g. a non-finite ratio smuggled in by a
/// broken or tampered worker) — the same check that guards every
/// insertion into the cache store.
#[test]
fn merge_reports_rejects_lossy_partials() {
    let dir = scratch("lossy-partial");
    let runner = Runner {
        validate: true,
        seeding: Seeding::PerCell(11),
        ..Runner::new(
            DeviceConfig {
                num_cus: 4,
                ..DeviceConfig::small()
            },
            WorkloadSize::Tiny,
            1,
        )
    };
    let plan = SweepPlan::new(registry::STRESS, &[axis::REMOTE_RATIO])
        .unwrap()
        .with_points(axis::REMOTE_RATIO, vec![0.0])
        .unwrap();
    let lowered = ExecutionPlan::lower_sweep(&runner, &plan);
    let spec = &shard::partition(&lowered, 1)[0];
    let partial = PartialReport::from_shard(spec, &execute_shard(spec));

    // Sanity: the healthy partial merges.
    assert!(Report::merge(std::slice::from_ref(&partial)).is_ok());

    // Replace one l1_hit_rate value with 1e999 (parses as a valid JSON
    // number token, decodes to +inf — exactly the lossy case).
    let text = partial.to_json();
    let pos = text.find("\"l1_hit_rate\":").unwrap() + "\"l1_hit_rate\":".len();
    let end = pos + text[pos..].find(',').unwrap();
    let tampered = format!("{}1e999{}", &text[..pos], &text[end..]);
    let path = dir.join("tampered.json");
    std::fs::write(&path, &tampered).unwrap();

    let out = srsp_bin()
        .args(["merge-reports", "--partial", path.to_str().unwrap()])
        .output()
        .expect("spawn merge-reports");
    assert!(!out.status.success(), "a lossy partial must not merge");
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("not finite"),
        "the lossy field must be named:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    let _ = std::fs::remove_dir_all(&dir);
}
