//! The hot-path speed campaign's acceptance gate: every host-side
//! optimization (decode-once interpreter, L1 fast paths, engine arenas)
//! must leave the *simulated* results untouched. The full ci-smoke
//! scenario matrix is run under the pre-decode reference interpreter and
//! under the decoded fast path, and the machine-readable reports must be
//! **byte-identical** — plus an optional golden-file pin (bless with
//! `SRSP_BLESS=1`) so a regression against history is caught even when
//! both paths drift together.
//!
//! The interpreter-path switch is process-global, so the before/after
//! comparison lives in ONE `#[test]` fn (sequential flips); the CLI
//! checks run the `srsp` binary in subprocesses and cannot race it.

use std::path::{Path, PathBuf};
use std::process::Command;

use srsp::config::DeviceConfig;
use srsp::coordinator::{full_grid, Seeding};
use srsp::harness::presets::{WorkloadSize, DEFAULT_SEED};
use srsp::harness::report::Report;
use srsp::harness::runner::Runner;
use srsp::jsonio::Json;
use srsp::sim::perfstats;

fn srsp_bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_srsp"))
}

/// A scratch directory unique to this test process + test name.
fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("srsp-test-{}-{name}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("create scratch dir");
    dir
}

/// Compare `actual` against the checked-in golden file, or (re)write it
/// when `SRSP_BLESS=1`. A missing golden is reported but not fatal, so
/// the suite stays runnable from a bare checkout before the first bless.
fn golden_check(name: &str, actual: &str) {
    let path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/golden")
        .join(name);
    if std::env::var_os("SRSP_BLESS").is_some() {
        std::fs::create_dir_all(path.parent().unwrap()).expect("create golden dir");
        std::fs::write(&path, actual).expect("write golden");
        eprintln!("blessed {}", path.display());
        return;
    }
    match std::fs::read_to_string(&path) {
        Ok(expected) => assert_eq!(
            expected,
            actual,
            "{} drifted from the checked-in golden; if the simulated-results change is \
             intended, re-bless with SRSP_BLESS=1",
            path.display()
        ),
        Err(_) => eprintln!(
            "golden file {} not checked in yet; run with SRSP_BLESS=1 to create it",
            path.display()
        ),
    }
}

/// The ci-smoke matrix (all registered workloads × the paper scenarios,
/// tiny scale, 8 CUs) with every oracle validated, under the selected
/// interpreter path.
fn ci_smoke_report(reference: bool) -> Report {
    let cfg = DeviceConfig {
        num_cus: 8,
        ..DeviceConfig::default()
    };
    let cells = full_grid(cfg.num_cus);
    perfstats::set_reference_paths(reference);
    let runner = Runner {
        validate: true,
        seeding: Seeding::Shared(DEFAULT_SEED),
        ..Runner::new(cfg, WorkloadSize::Tiny, 2)
    };
    let results = runner.run_cells(&cells);
    perfstats::set_reference_paths(false);
    Report::from_cells(&results)
}

#[test]
fn ci_smoke_matrix_byte_identical_across_interpreter_paths() {
    let reference = ci_smoke_report(true);
    let decoded = ci_smoke_report(false);

    for r in &decoded.rows {
        assert!(
            r.converged && r.validated == Some(true),
            "{}/{} failed its oracle on the decoded path",
            r.app,
            r.scenario
        );
    }
    assert_eq!(
        reference.to_csv(),
        decoded.to_csv(),
        "CSV report differs between reference and decoded interpreter paths"
    );
    assert_eq!(
        reference.to_json(),
        decoded.to_json(),
        "JSON report differs between reference and decoded interpreter paths"
    );

    golden_check("ci_smoke_tiny8.csv", &decoded.to_csv());
    golden_check("ci_smoke_tiny8.json", &decoded.to_json());
}

/// End-to-end CLI: `srsp bench hotpath` (positional kind + scoped flags)
/// writes a schema-versioned JSON artifact with the advertised fields.
#[test]
fn bench_cli_emits_versioned_artifact() {
    let dir = scratch("bench-cli");
    let out = dir.join("BENCH_hotpath_tiny.json");
    let status = srsp_bin()
        .args([
            "bench",
            "hotpath",
            "--size",
            "tiny",
            "--app",
            "stress",
            "--scenario",
            "scope",
            "--repeats",
            "2",
            "--warmup",
            "0",
        ])
        .arg("--out")
        .arg(&out)
        .status()
        .expect("run srsp bench");
    assert!(status.success(), "srsp bench hotpath failed: {status}");

    let text = std::fs::read_to_string(&out).expect("read bench artifact");
    let doc = srsp::jsonio::parse(&text).expect("bench artifact must be valid JSON");
    assert_eq!(doc.get("schema").and_then(Json::as_u64), Ok(1));
    assert_eq!(
        doc.get("kind").and_then(Json::as_str),
        Ok("hotpath"),
        "artifact kind"
    );
    let cells = doc.get("cells").and_then(Json::arr).expect("cells array");
    assert_eq!(cells.len(), 1, "one app × one scenario");
    let cell = &cells[0];
    for key in ["median_secs", "cells_per_sec", "minstr_per_sec"] {
        assert!(
            cell.get(key).and_then(Json::as_f64).is_ok(),
            "cell missing numeric '{key}'"
        );
    }
    assert!(
        doc.get("totals")
            .and_then(|t| t.get("cells_per_sec"))
            .and_then(Json::as_f64)
            .is_ok(),
        "totals missing cells_per_sec"
    );
}

/// The bench measurement flags are scoped: any other command rejects
/// them instead of silently ignoring them.
#[test]
fn bench_flags_rejected_elsewhere() {
    let out = srsp_bin()
        .args(["ci-smoke", "--repeats", "3"])
        .output()
        .expect("run srsp");
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(
        err.contains("--repeats applies to bench"),
        "unexpected stderr: {err}"
    );

    let out = srsp_bin()
        .args(["bench", "no-such-kind"])
        .output()
        .expect("run srsp");
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(
        err.contains("unknown bench kind"),
        "unexpected stderr: {err}"
    );
}
